//! Serving benchmark: (a) KV-cache incremental decode vs full-prefix
//! re-forward per token, (b) batched multi-row prefill vs token-by-token
//! prefill (admission latency), (c) closed-loop continuous-batching load
//! test, dense vs CSR backends at 0/50/70/90% sparsity, with tokens/s and
//! p50/p95/p99 token latency, (d) concurrent TCP clients with healthz
//! latency under load, (e) three-way dense vs CSR vs packed-N:M race on
//! one 2:4-pruned model — all three backends must emit identical token
//! streams, and packed decode must not lose to CSR. Results feed
//! EXPERIMENTS.md §Serve.
//!
//!     ALPS_THREADS=4 cargo bench --bench bench_serve
//!     cargo bench --bench bench_serve -- --smoke   # reduced CI workload
//!
//! Uses a synthetic alps-tiny model, so no artifacts are required.

use alps::config::ModelConfig;
use alps::linalg::matmul::num_threads;
use alps::model::{Model, SparseModel};
use alps::pruning::projection::{nm_project, topk_project};
use alps::sparse::NmModel;
use alps::serve::{tcp, Batcher, Engine, SamplingParams, TcpConfig};
use alps::util::table::Table;
use alps::util::{Rng, Timer};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

/// Copy of `model` with every prunable matrix magnitude-pruned to `density`.
fn prune_model(model: &Model, density: f64) -> anyhow::Result<Model> {
    let mut w = model.weights.clone();
    for name in model.prunable_names() {
        let mat = w.matrix(&name)?;
        let keep = ((mat.data.len() as f64) * density).round() as usize;
        w.set_matrix(&name, &topk_project(&mat, keep.max(1)))?;
    }
    Model::new(model.cfg.clone(), w)
}

/// Copy of `model` with every prunable matrix 2:4 magnitude-projected —
/// the same checkpoint serves all three backends in section (e).
fn prune_model_nm(model: &Model, n: usize, m: usize) -> anyhow::Result<Model> {
    let mut w = model.weights.clone();
    for name in model.prunable_names() {
        w.set_matrix(&name, &nm_project(&w.matrix(&name)?, n, m))?;
    }
    Model::new(model.cfg.clone(), w)
}

/// Closed-loop load: `n_req` requests of `prompt_len` random tokens, each
/// generating `max_new` tokens through the continuous batcher.
fn run_load(
    engine: &Engine,
    n_req: usize,
    prompt_len: usize,
    max_new: usize,
    max_batch: usize,
) -> anyhow::Result<(f64, f64, f64, f64, usize)> {
    let vocab = engine.model().cfg.vocab;
    let mut rng = Rng::new(7);
    let mut batcher = Batcher::new(engine, max_batch);
    for _ in 0..n_req {
        let prompt: Vec<u16> = (0..prompt_len).map(|_| rng.below(vocab) as u16).collect();
        batcher.submit(prompt, SamplingParams { max_new_tokens: max_new, ..Default::default() });
    }
    let responses = batcher.run_to_completion()?;
    assert_eq!(responses.len(), n_req);
    let m = &batcher.metrics;
    Ok((
        m.tokens_per_sec(),
        m.token_latency_ms(50.0),
        m.token_latency_ms(95.0),
        m.token_latency_ms(99.0),
        m.requests_completed(),
    ))
}

/// (b) admission latency: batched multi-row prefill vs token-by-token.
fn bench_prefill(model: &Model, prompt_lens: &[usize], reps: usize) -> anyhow::Result<()> {
    println!("\nprefill (admission) latency: batched [prompt, d] passes vs token-by-token");
    let mut t = Table::new(&["backend", "prompt", "stepwise ms", "batched ms", "speedup"]);
    let pruned = prune_model(model, 0.3)?;
    for (label, m) in [("dense", model), ("sparse(0.30)", &pruned)] {
        let engine = if label == "dense" { Engine::dense(m)? } else { Engine::sparse(m)? };
        let dec = engine.decoder();
        for &plen in prompt_lens {
            let prompt: Vec<u16> = (0..plen).map(|i| (i * 7 % m.cfg.vocab) as u16).collect();
            let mut step_secs = 0.0;
            let mut batch_secs = 0.0;
            for _ in 0..reps {
                let timer = Timer::start();
                let mut c = dec.new_cache();
                let a = dec.prefill(&mut c, &prompt)?;
                step_secs += timer.elapsed_secs();
                let timer = Timer::start();
                let mut c = dec.new_cache();
                let b = dec.prefill_batch(&mut c, &prompt)?;
                batch_secs += timer.elapsed_secs();
                let drift = a
                    .iter()
                    .zip(&b)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                assert!(drift < 1e-3, "prefill_batch diverged: max |d|={drift}");
            }
            t.row(&[
                label.to_string(),
                plen.to_string(),
                format!("{:.3}", step_secs / reps as f64 * 1e3),
                format!("{:.3}", batch_secs / reps as f64 * 1e3),
                format!("{:.1}x", step_secs / batch_secs.max(1e-12)),
            ]);
        }
    }
    t.print();
    Ok(())
}

/// (d) concurrent TCP clients against the threaded front-end, measuring
/// healthz latency while generations are in flight.
fn bench_tcp_concurrency(
    model: &Model,
    n_clients: usize,
    reqs_per_client: usize,
    max_new: usize,
) -> anyhow::Result<()> {
    let engine = Engine::dense(model)?;
    let params = SamplingParams { max_new_tokens: max_new, ..Default::default() };
    let cfg = TcpConfig::default();
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    println!(
        "\nconcurrent TCP load: {n_clients} clients x {reqs_per_client} reqs, {max_new} new tokens each"
    );
    let wall = Timer::start();
    let mut healthz_ms: Vec<f64> = Vec::new();
    std::thread::scope(|s| -> anyhow::Result<()> {
        let server = s.spawn(|| tcp::serve(listener, &engine, &params, &cfg));
        let clients: Vec<_> = (0..n_clients)
            .map(|ci| {
                s.spawn(move || -> std::io::Result<usize> {
                    let stream = TcpStream::connect(addr)?;
                    // a dropped result line must fail the bench, not hang CI
                    stream.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
                    let mut r = BufReader::new(stream.try_clone()?);
                    let mut w = stream;
                    let mut line = String::new();
                    for k in 0..reqs_per_client {
                        writeln!(w, "{} {} {}", 1 + ci, 2 + k, 3)?;
                        line.clear();
                        r.read_line(&mut line)?;
                    }
                    writeln!(w, "run")?;
                    let mut ok = 0;
                    for _ in 0..reqs_per_client {
                        line.clear();
                        r.read_line(&mut line)?;
                        if line.starts_with("ok ") {
                            ok += 1;
                        }
                    }
                    Ok(ok)
                })
            })
            .collect();
        // probe healthz while the clients are decoding
        for _ in 0..8 {
            let t = Timer::start();
            let stream = TcpStream::connect(addr)?;
            stream.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
            let mut r = BufReader::new(stream.try_clone()?);
            let mut w = stream;
            write!(w, "GET /healthz HTTP/1.1\r\n\r\n")?;
            let mut status = String::new();
            r.read_line(&mut status)?;
            healthz_ms.push(t.elapsed_secs() * 1e3);
            assert!(status.starts_with("HTTP/1.1 200"), "healthz: {status}");
            let mut rest = String::new();
            let _ = r.read_to_string(&mut rest); // drain so the server write completes
        }
        let mut served = 0;
        for c in clients {
            served += c.join().expect("client thread panicked")?;
        }
        assert_eq!(served, n_clients * reqs_per_client, "not all requests answered");
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(std::time::Duration::from_secs(60)))?;
        let mut w = stream.try_clone()?;
        writeln!(w, "shutdown")?;
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line)?;
        server.join().expect("server thread panicked")?;
        Ok(())
    })?;
    healthz_ms.sort_by(|a, b| a.total_cmp(b));
    println!(
        "all {} requests served in {:.3}s; healthz under load: p50 {:.3} ms, max {:.3} ms",
        n_clients * reqs_per_client,
        wall.elapsed_secs(),
        healthz_ms[healthz_ms.len() / 2],
        healthz_ms.last().copied().unwrap_or(f64::NAN),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("== bench_serve: batched sparse serving{} ==", if smoke { " (smoke)" } else { "" });
    println!("threads: {} (pin with ALPS_THREADS for reproducible runs)\n", num_threads());
    let model = Model::random(ModelConfig::preset("alps-tiny")?, 0)?;

    // ---------- (a) KV-cache decode vs full-prefix re-forward
    let engine = Engine::dense(&model)?;
    let prompt: Vec<u16> = (0..16u16).map(|i| i * 7 % 512).collect();
    let gen_n = if smoke { 8 } else { 32 };
    let params = SamplingParams { max_new_tokens: gen_n, ..Default::default() };
    let timer = Timer::start();
    let g = engine.generate(&prompt, &params, 0)?;
    let kv_secs = timer.elapsed_secs();

    let timer = Timer::start();
    let mut ids = prompt.clone();
    let mut naive = Vec::new();
    let greedy = SamplingParams::default();
    let mut rng = Rng::new(0); // unused by greedy sampling, required by the API
    for _ in 0..gen_n {
        let logits = model.logits(&ids)?;
        let tok = alps::serve::sample_token(logits.row(logits.rows - 1), &greedy, &mut rng);
        ids.push(tok);
        naive.push(tok);
    }
    let naive_secs = timer.elapsed_secs();
    assert_eq!(g.tokens, naive, "KV decode diverged from full-prefix forward");
    println!(
        "decode {gen_n} tokens (prompt {}): KV-cache {:.4}s vs full-prefix {:.4}s -> {:.1}x",
        prompt.len(),
        kv_secs,
        naive_secs,
        naive_secs / kv_secs.max(1e-12),
    );

    // ---------- (b) batched vs token-by-token prefill
    if smoke {
        bench_prefill(&model, &[16], 2)?;
    } else {
        bench_prefill(&model, &[16, 48, 96], 5)?;
    }

    // ---------- (c) continuous-batching load, dense vs CSR per density
    let (n_req, prompt_len, max_new, max_batch) =
        if smoke { (6, 8, 6, 4) } else { (24, 16, 24, 8) };
    println!(
        "\nclosed loop: {n_req} reqs x {max_new} new tokens, prompt {prompt_len}, batch {max_batch}"
    );
    let mut t = Table::new(&[
        "density", "backend", "tok/s", "p50 ms", "p95 ms", "p99 ms", "weight MiB",
    ]);
    let densities: &[f64] = if smoke { &[1.0, 0.3] } else { &[1.0, 0.5, 0.3, 0.1] };
    for &density in densities {
        let m = prune_model(&model, density)?;
        let (sparse_bytes, dense_bytes) = SparseModel::from_model(&m)?.bytes_sparse_vs_dense();
        let mut tps = [0.0f64; 2];
        for (bi, sparse) in [false, true].into_iter().enumerate() {
            let engine = if sparse { Engine::sparse(&m)? } else { Engine::dense(&m)? };
            let (tok_s, p50, p95, p99, reqs) =
                run_load(&engine, n_req, prompt_len, max_new, max_batch)?;
            assert_eq!(reqs, n_req);
            tps[bi] = tok_s;
            let bytes = if sparse { sparse_bytes } else { dense_bytes };
            t.row(&[
                format!("{density:.2}"),
                engine.label().to_string(),
                format!("{tok_s:.0}"),
                format!("{p50:.3}"),
                format!("{p95:.3}"),
                format!("{p99:.3}"),
                format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
            ]);
        }
        println!(
            "density {density:.2}: sparse/dense throughput ratio {:.2}x",
            tps[1] / tps[0].max(1e-12)
        );
    }
    t.print();
    println!("\n(CSR should cross over dense below ~0.5 density on this kernel)");

    // ---------- (d) concurrent TCP clients + healthz under load
    if smoke {
        bench_tcp_concurrency(&model, 4, 2, 4)?;
    } else {
        bench_tcp_concurrency(&model, 8, 4, 16)?;
    }

    // ---------- (e) dense vs CSR vs packed N:M at matched 2:4
    bench_nm_race(&model, n_req, prompt_len, max_new, max_batch)?;
    Ok(())
}

/// (e) the packed-format payoff: one 2:4-pruned checkpoint served by all
/// three backends. Token streams must be identical (packed N:M is
/// bit-identical to CSR by construction), and packed decode throughput
/// must be at least CSR's — same nnz, smaller index metadata, no indptr.
fn bench_nm_race(
    model: &Model,
    n_req: usize,
    prompt_len: usize,
    max_new: usize,
    max_batch: usize,
) -> anyhow::Result<()> {
    let m = prune_model_nm(model, 2, 4)?;
    let n_layers = m.prunable_names().len();
    let e_dense = Engine::dense(&m)?;
    let e_csr = Engine::sparse(&m)?;
    let e_nm = Engine::nm(&m, 2, 4)?;
    assert!(
        e_nm.label().contains(&format!("{n_layers}/{n_layers} packed")),
        "2:4-projected model must pack every layer, got '{}'",
        e_nm.label()
    );

    // exactness gate before timing: identical greedy streams on all three
    let params = SamplingParams { max_new_tokens: max_new, ..Default::default() };
    for prompt in [vec![1u16, 2, 3], vec![500, 7, 123, 9], vec![42; 6]] {
        let td = e_dense.generate(&prompt, &params, 0)?.tokens;
        let tc = e_csr.generate(&prompt, &params, 0)?.tokens;
        let tn = e_nm.generate(&prompt, &params, 0)?.tokens;
        assert_eq!(tc, tn, "packed N:M diverged from CSR on {prompt:?}");
        assert_eq!(td, tn, "packed N:M diverged from dense on {prompt:?}");
    }

    let (sparse_bytes, dense_bytes) = SparseModel::from_model(&m)?.bytes_sparse_vs_dense();
    let nm_bytes = NmModel::from_model(&m, 2, 4)?.bytes_packed_vs_dense().0;
    println!("\nmatched 2:4 race: dense vs CSR vs packed N:M (same checkpoint, greedy-identical)");
    let mut t = Table::new(&["backend", "tok/s", "p50 ms", "p95 ms", "p99 ms", "weight MiB"]);
    let mut best = [0.0f64; 3];
    for (bi, (engine, bytes)) in
        [(&e_dense, dense_bytes), (&e_csr, sparse_bytes), (&e_nm, nm_bytes)]
            .into_iter()
            .enumerate()
    {
        // best-of-3 to damp scheduler noise; the exactness gate above is
        // what makes the three rows comparable
        let mut rows = Vec::new();
        for _ in 0..3 {
            rows.push(run_load(engine, n_req, prompt_len, max_new, max_batch)?);
        }
        rows.sort_by(|a, b| b.0.total_cmp(&a.0));
        let (tok_s, p50, p95, p99, reqs) = rows[0];
        assert_eq!(reqs, n_req);
        best[bi] = tok_s;
        t.row(&[
            engine.label().to_string(),
            format!("{tok_s:.0}"),
            format!("{p50:.3}"),
            format!("{p95:.3}"),
            format!("{p99:.3}"),
            format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
        ]);
    }
    t.print();
    println!(
        "packed N:M vs CSR {:.2}x, vs dense {:.2}x",
        best[2] / best[1].max(1e-12),
        best[2] / best[0].max(1e-12),
    );
    assert!(
        best[2] >= best[1],
        "packed N:M decode ({:.0} tok/s) lost to CSR ({:.0} tok/s) at matched 2:4",
        best[2],
        best[1]
    );
    Ok(())
}
