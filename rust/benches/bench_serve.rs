//! Serving benchmark: (a) KV-cache incremental decode vs full-prefix
//! re-forward per token, (b) closed-loop continuous-batching load test,
//! dense vs CSR backends at 0/50/70/90% sparsity, with tokens/s and
//! p50/p95/p99 token latency. Results feed EXPERIMENTS.md §Serve.
//!
//!     ALPS_THREADS=4 cargo bench --bench bench_serve
//!
//! Uses a synthetic alps-tiny model, so no artifacts are required.

use alps::config::ModelConfig;
use alps::linalg::matmul::num_threads;
use alps::model::{Model, SparseModel};
use alps::pruning::projection::topk_project;
use alps::serve::{Batcher, Engine, SamplingParams};
use alps::util::table::Table;
use alps::util::{Rng, Timer};

/// Copy of `model` with every prunable matrix magnitude-pruned to `density`.
fn prune_model(model: &Model, density: f64) -> anyhow::Result<Model> {
    let mut w = model.weights.clone();
    for name in model.prunable_names() {
        let mat = w.matrix(&name)?;
        let keep = ((mat.data.len() as f64) * density).round() as usize;
        w.set_matrix(&name, &topk_project(&mat, keep.max(1)))?;
    }
    Model::new(model.cfg.clone(), w)
}

/// Closed-loop load: `n_req` requests of `prompt_len` random tokens, each
/// generating `max_new` tokens through the continuous batcher.
fn run_load(
    engine: &Engine,
    n_req: usize,
    prompt_len: usize,
    max_new: usize,
    max_batch: usize,
) -> anyhow::Result<(f64, f64, f64, f64, usize)> {
    let vocab = engine.model().cfg.vocab;
    let mut rng = Rng::new(7);
    let mut batcher = Batcher::new(engine, max_batch);
    for _ in 0..n_req {
        let prompt: Vec<u16> = (0..prompt_len).map(|_| rng.below(vocab) as u16).collect();
        batcher.submit(prompt, SamplingParams { max_new_tokens: max_new, ..Default::default() });
    }
    let responses = batcher.run_to_completion()?;
    assert_eq!(responses.len(), n_req);
    let m = &batcher.metrics;
    Ok((
        m.tokens_per_sec(),
        m.token_latency_ms(50.0),
        m.token_latency_ms(95.0),
        m.token_latency_ms(99.0),
        m.requests_completed(),
    ))
}

fn main() -> anyhow::Result<()> {
    println!("== bench_serve: batched sparse serving ==");
    println!("threads: {} (pin with ALPS_THREADS for reproducible runs)\n", num_threads());
    let model = Model::random(ModelConfig::preset("alps-tiny")?, 0)?;

    // ---------- (a) KV-cache decode vs full-prefix re-forward
    let engine = Engine::dense(&model)?;
    let prompt: Vec<u16> = (0..16u16).map(|i| i * 7 % 512).collect();
    let gen_n = 32;
    let params = SamplingParams { max_new_tokens: gen_n, ..Default::default() };
    let timer = Timer::start();
    let g = engine.generate(&prompt, &params, 0)?;
    let kv_secs = timer.elapsed_secs();

    let timer = Timer::start();
    let mut ids = prompt.clone();
    let mut naive = Vec::new();
    let greedy = SamplingParams::default();
    let mut rng = Rng::new(0); // unused by greedy sampling, required by the API
    for _ in 0..gen_n {
        let logits = model.logits(&ids)?;
        let tok = alps::serve::sample_token(logits.row(logits.rows - 1), &greedy, &mut rng);
        ids.push(tok);
        naive.push(tok);
    }
    let naive_secs = timer.elapsed_secs();
    assert_eq!(g.tokens, naive, "KV decode diverged from full-prefix forward");
    println!(
        "decode {gen_n} tokens (prompt {}): KV-cache {:.4}s vs full-prefix {:.4}s -> {:.1}x",
        prompt.len(),
        kv_secs,
        naive_secs,
        naive_secs / kv_secs.max(1e-12),
    );

    // ---------- (b) continuous-batching load, dense vs CSR per density
    let (n_req, prompt_len, max_new, max_batch) = (24, 16, 24, 8);
    println!(
        "\nclosed loop: {n_req} reqs x {max_new} new tokens, prompt {prompt_len}, batch {max_batch}"
    );
    let mut t = Table::new(&[
        "density", "backend", "tok/s", "p50 ms", "p95 ms", "p99 ms", "weight MiB",
    ]);
    for density in [1.0f64, 0.5, 0.3, 0.1] {
        let m = prune_model(&model, density)?;
        let (sparse_bytes, dense_bytes) = SparseModel::from_model(&m)?.bytes_sparse_vs_dense();
        let mut tps = [0.0f64; 2];
        for (bi, sparse) in [false, true].into_iter().enumerate() {
            let engine = if sparse { Engine::sparse(&m)? } else { Engine::dense(&m)? };
            let (tok_s, p50, p95, p99, reqs) =
                run_load(&engine, n_req, prompt_len, max_new, max_batch)?;
            assert_eq!(reqs, n_req);
            tps[bi] = tok_s;
            let bytes = if sparse { sparse_bytes } else { dense_bytes };
            t.row(&[
                format!("{density:.2}"),
                engine.label().to_string(),
                format!("{tok_s:.0}"),
                format!("{p50:.3}"),
                format!("{p95:.3}"),
                format!("{p99:.3}"),
                format!("{:.2}", bytes as f64 / (1024.0 * 1024.0)),
            ]);
        }
        println!(
            "density {density:.2}: sparse/dense throughput ratio {:.2}x",
            tps[1] / tps[0].max(1e-12)
        );
    }
    t.print();
    println!("\n(CSR should cross over dense below ~0.5 density on this kernel)");
    Ok(())
}
