//! Ablations over the design choices DESIGN.md calls out:
//!   A1 — the eq.-28 rho-update scheme vs fixed rho (the paper's Sec. 3.2
//!        motivation: small rho explores, schedule converges)
//!   A2 — PCG refinement iterations (0/5/10/20) vs error
//!   A3 — B.1 diagonal scaling on vs off
//!   A4 — calibration-set size vs downstream layer error
//!   A5 — sparse CSR inference vs dense at several sparsities
//!
//!     cargo bench --bench bench_ablations

use alps::bench::{bench, paper_layer_problem, synthetic_problem};
use alps::config::{AlpsConfig, SparsityTarget};
use alps::linalg::solve::pcg_support;
use alps::model::sparse_infer::SparseModel;
use alps::model::Model;
use alps::pruning::alps::Alps;
use alps::pruning::magnitude::MagnitudePruning;
use alps::pruning::{LayerProblem, MethodSpec, PruneMethod, PruneSession};
use alps::util::table::{fmt_sig, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let p = paper_layer_problem()?;
    let target = SparsityTarget::Unstructured(0.7);

    // ---------------- A1: rho schedule
    println!("== A1: rho-update scheme (eq. 28) vs fixed rho, s=0.7 ==\n");
    let mut t = Table::new(&["variant", "rel-error", "admm iters"]);
    for (label, cfg) in [
        ("eq-28 schedule (paper)", AlpsConfig::default()),
        (
            "fixed rho=0.1",
            AlpsConfig { rho_factors: (1.0, 1.0, 1.0), max_iters: 120, ..Default::default() },
        ),
        (
            "fixed rho=10",
            AlpsConfig {
                rho0: 10.0,
                rho_factors: (1.0, 1.0, 1.0),
                max_iters: 120,
                ..Default::default()
            },
        ),
        (
            "aggressive x2.0",
            AlpsConfig { rho_factors: (2.0, 2.0, 2.0), ..Default::default() },
        ),
    ] {
        let alps = Alps::with_config(cfg);
        let (w, trace) = alps.prune_traced(&p, target)?;
        t.row(&[
            label.to_string(),
            fmt_sig(p.rel_error(&w)),
            trace.admm_iters.to_string(),
        ]);
    }
    t.print();
    println!("expect: schedule matches-or-beats fixed-rho at far fewer iterations.\n");

    // ---------------- A2: PCG iterations
    println!("== A2: PCG refinement iterations (MP support, s=0.7) ==\n");
    let w_mp = MagnitudePruning.prune(&p, target)?;
    let mask = w_mp.support_mask();
    let mut t = Table::new(&["pcg iters", "rel-error", "secs"]);
    for iters in [0usize, 5, 10, 20, 40] {
        let stats = bench(0, 3, || pcg_support(&p.h, &p.g, &w_mp, &mask, iters, 1e-14));
        let (w, _) = pcg_support(&p.h, &p.g, &w_mp, &mask, iters, 1e-14);
        t.row(&[
            iters.to_string(),
            fmt_sig(p.rel_error(&w)),
            format!("{:.4}", stats.median()),
        ]);
    }
    t.print();
    println!("expect: monotone error decrease, diminishing after ~10 (the paper's pick).\n");

    // ---------------- A3: diagonal scaling
    println!("== A3: B.1 diagonal scaling ==\n");
    let mut t = Table::new(&["scaling", "rel-error", "admm iters"]);
    for (label, on) in [("on (paper)", true), ("off", false)] {
        let alps = Alps::with_config(AlpsConfig { diag_scaling: on, ..Default::default() });
        let (w, trace) = alps.prune_traced(&p, target)?;
        t.row(&[
            label.to_string(),
            fmt_sig(p.rel_error(&w)),
            trace.admm_iters.to_string(),
        ]);
    }
    t.print();
    println!("expect: scaling improves error and/or convergence on anisotropic X.\n");

    // ---------------- A4: calibration size
    println!("== A4: calibration rows vs layer error (synthetic 256x128) ==\n");
    let mut t = Table::new(&["calib rows", "ALPS rel-error", "MP rel-error"]);
    for rows in [64usize, 256, 1024, 4096] {
        let p = synthetic_problem(256, 128, rows, 9);
        let w_alps = Alps::default().prune(&p, target)?;
        let w_mp = MagnitudePruning.prune(&p, target)?;
        t.row(&[
            rows.to_string(),
            fmt_sig(p.rel_error(&w_alps)),
            fmt_sig(p.rel_error(&w_mp)),
        ]);
    }
    t.print();
    println!(
        "note: below rows=n_in the gram is rank-deficient and ALPS can fit the\n\
         calibration outputs almost exactly; as rows grow the problem becomes\n\
         overdetermined and the error saturates. MP is calibration-blind at\n\
         every size — the gap is the value of the calibration data.\n"
    );

    // ---------------- A5: sparse inference
    if Path::new("artifacts/model_alps-tiny.bin").exists() {
        println!("== A5: CSR sparse inference vs dense (alps-tiny) ==\n");
        let dir = Path::new("artifacts");
        let corpus = alps::data::Corpus::load(&dir.join("corpus.bin"))?;
        let calib = alps::data::sample_windows(corpus.split("train")?, 8, 128, 5);
        let ids: Vec<u16> = corpus.split("wikitext2-like")?[..128].to_vec();
        let mut t = Table::new(&[
            "sparsity", "density", "dense s/seq", "csr s/seq", "ratio", "mem ratio",
        ]);
        for s in [0.5f64, 0.7, 0.9] {
            let mut model = Model::load(dir, "alps-tiny")?;
            PruneSession::builder()
                .calib(calib.clone())
                .target(SparsityTarget::Unstructured(s))
                .method(MethodSpec::Alps(AlpsConfig::default()))
                .run(&mut model)?;
            let sm = SparseModel::from_model(&model)?;
            let dense_s = bench(1, 3, || model.nll(&ids).unwrap()).median();
            let csr_s = bench(1, 3, || sm.nll(&ids).unwrap()).median();
            let (sb, db) = sm.bytes_sparse_vs_dense();
            t.row(&[
                format!("{s:.1}"),
                format!("{:.2}", sm.density()),
                format!("{dense_s:.3}"),
                format!("{csr_s:.3}"),
                format!("{:.2}x", dense_s / csr_s),
                format!("{:.2}x", db as f64 / sb as f64),
            ]);
        }
        t.print();
        println!(
            "note: memory shrinks ~1/density as expected; on this CPU the\n\
             vectorized dense micro-kernel outruns scalar CSR until density\n\
             ~0.1 (time ratio -> 1 at s=0.9) — the paper's inference-speed\n\
             claim needs sparse-tensor hardware (2:4 units), which is why it\n\
             targets the N:M format."
        );
    } else {
        println!("== A5 skipped: artifacts not built ==");
    }

    let _ = LayerProblem::from_gram; // keep import shape stable
    Ok(())
}
