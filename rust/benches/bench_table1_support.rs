//! Table 1 (left): support quality — optimal weights constrained to each
//! method's support, via exact backsolve.
//!
//!     cargo bench --bench bench_table1_support

use alps::bench::paper_layer_problem;
use alps::config::SparsityTarget;
use alps::pruning::{backsolve, MethodSpec};
use alps::util::table::{fmt_sig, Table};

fn main() -> anyhow::Result<()> {
    let p = paper_layer_problem()?;
    println!(
        "== Table 1 (left): error of the OPTIMAL weights on each method's support ==\n"
    );
    let mut table = Table::new(&["sparsity", "MP", "Wanda", "SparseGPT", "DSnoT", "ALPS", "ALPS gain vs best"]);
    for s in [0.5f64, 0.6, 0.7, 0.8, 0.9] {
        let target = SparsityTarget::Unstructured(s);
        let mut errs = Vec::new();
        for spec in MethodSpec::all() {
            let w = spec.prune(&p, target)?;
            let opt = backsolve::solve_on_support(&p, &w.support_mask())?;
            errs.push(p.rel_error(&opt));
        }
        let best_heuristic = errs[..4].iter().cloned().fold(f64::INFINITY, f64::min);
        let gain = 100.0 * (1.0 - errs[4] / best_heuristic.max(1e-12));
        let mut row = vec![format!("{s:.1}")];
        row.extend(errs.iter().map(|e| fmt_sig(*e)));
        row.push(format!("{gain:+.1}%"));
        table.row(&row);
    }
    table.print();
    println!("\npaper shape: ALPS support 20-40% lower error than other supports.");
    Ok(())
}
