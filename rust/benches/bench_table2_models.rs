//! Table 2 (+ appendix Tables 4-9): end-to-end pruning of the model family
//! at 70% sparsity (override with ALPS_SPARSITY) — perplexity on the three
//! eval sets and accuracy on the four zero-shot tasks, for every method.
//!
//!     cargo bench --bench bench_table2_models
//!     ALPS_SPARSITY=0.5 ALPS_MODELS=alps-tiny cargo bench --bench bench_table2_models

use alps::bench::artifacts_ready;
use alps::config::SparsityTarget;
use alps::data::{sample_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::Model;
use alps::pruning::{MethodSpec, PruneSession};
use alps::util::table::{fmt_sig, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    if !artifacts_ready() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return Ok(());
    }
    let sparsity = std::env::var("ALPS_SPARSITY").unwrap_or_else(|_| "0.7".into());
    let models_env = std::env::var("ALPS_MODELS")
        .unwrap_or_else(|_| "alps-tiny,alps-small".into());
    let target = SparsityTarget::parse(&sparsity)?;
    let dir = Path::new("artifacts");
    let corpus = Corpus::load(&dir.join("corpus.bin"))?;

    println!(
        "== Table 2: one-shot unstructured pruning at {} sparsity ==\n",
        target.label()
    );
    let mut table = Table::new(&[
        "model", "method", "wikitext2↓", "ptb↓", "c4↓",
        "lambada↑", "piqa↑", "arc-e↑", "arc-c↑",
    ]);
    for model_name in models_env.split(',') {
        let dense = Model::load(dir, model_name)?;
        let calib = sample_windows(corpus.split("train")?, 16, dense.cfg.seq_len, 0xCA11B);
        let eval_ids = corpus.split("wikitext2-like")?;
        let zs_tasks =
            tasks::standard_tasks(eval_ids, 30, dense.cfg.seq_len, dense.cfg.vocab, 7);

        let mut rows: Vec<(String, Vec<String>)> = Vec::new();
        rows.push(("dense".into(), eval_row(&dense, &corpus, &zs_tasks)?));
        for spec in MethodSpec::all() {
            let mut model = Model::load(dir, model_name)?;
            PruneSession::builder()
                .calib(calib.clone())
                .target(target)
                .method(spec.clone())
                .run(&mut model)?;
            rows.push((spec.label().into(), eval_row(&model, &corpus, &zs_tasks)?));
            eprintln!("  done {model_name}/{}", spec.label());
        }
        for (method, vals) in rows {
            let mut row = vec![model_name.to_string(), method];
            row.extend(vals);
            table.row(&row);
        }
    }
    table.print();
    println!("\npaper shape: ALPS best (lowest ppl, highest acc) on nearly every cell at ≥0.7 sparsity.");
    Ok(())
}

fn eval_row(
    model: &Model,
    corpus: &Corpus,
    zs_tasks: &[tasks::Task],
) -> anyhow::Result<Vec<String>> {
    let mut out = Vec::new();
    for split in Corpus::eval_split_names() {
        out.push(fmt_sig(perplexity(model, corpus.split(split)?)?));
    }
    for task in zs_tasks {
        out.push(format!("{:.1}", zero_shot_accuracy(model, task)? * 100.0));
    }
    Ok(out)
}
