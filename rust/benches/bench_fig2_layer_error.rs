//! Figure 2: relative reconstruction error vs sparsity for one real layer
//! (paper: OPT-13B self_attn.k_proj; here: alps-small blocks.0.mlp.w2),
//! all five methods at sparsities 0.5-0.9.
//!
//!     cargo bench --bench bench_fig2_layer_error

use alps::bench::paper_layer_problem;
use alps::config::SparsityTarget;
use alps::pruning::MethodSpec;
use alps::util::table::{fmt_sig, Table};

fn main() -> anyhow::Result<()> {
    let p = paper_layer_problem()?;
    println!(
        "== Figure 2: relative reconstruction error vs sparsity ({}x{} layer) ==\n",
        p.n_in(),
        p.n_out()
    );
    let mut table = Table::new(&["sparsity", "MP", "Wanda", "SparseGPT", "DSnoT", "ALPS"]);
    let mut alps_beats_all = true;
    for s in [0.5f64, 0.6, 0.7, 0.8, 0.9] {
        let target = SparsityTarget::Unstructured(s);
        let mut row = vec![format!("{s:.1}")];
        let mut errs = Vec::new();
        for spec in MethodSpec::all() {
            let w = spec.prune(&p, target)?;
            errs.push(p.rel_error(&w));
            row.push(fmt_sig(*errs.last().unwrap()));
        }
        let alps_err = errs[4];
        if errs[..4].iter().any(|e| *e < alps_err) {
            alps_beats_all = false;
        }
        table.row(&row);
    }
    table.print();
    println!(
        "\npaper shape: ALPS lowest at every sparsity, gap widening with s \
         (e.g. paper: 7.6% vs 12% vs >20% at s=0.8). ALPS wins everywhere here: {}",
        alps_beats_all
    );
    Ok(())
}
