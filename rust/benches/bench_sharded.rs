//! Distributed pruning benchmark: layer-solve throughput of the native
//! in-process engine vs a [`ShardedEngine`] over loopback worker pools of
//! 1 and 2 members, plus the wire/codec cost per layer — including the
//! protocol comparison (v2+) of gram-on-coordinator vs gram-on-worker
//! (`--ship-activations`) payload sizes and wall time. Loopback makes the
//! transport cost visible without hiding it behind real network latency —
//! the point is to bound the protocol overhead, and to verify (every run)
//! that sharded results stay bit-identical to native on both calibration
//! paths.
//!
//!     cargo bench --bench bench_sharded
//!     cargo bench --bench bench_sharded -- --smoke   # reduced CI workload
//!
//! No artifacts required (synthetic layer problems).

use alps::bench::synthetic_problem;
use alps::config::{AlpsConfig, SparsityTarget};
use alps::coordinator::{ShardedConfig, ShardedEngine};
use alps::pruning::wire::{encode_solve, CalibRef};
use alps::pruning::worker::{Worker, WorkerConfig};
use alps::pruning::{Engine, LayerJob, MethodSpec, NativeEngine};
use alps::util::table::Table;
use alps::util::Timer;
use std::net::TcpListener;
use std::sync::Arc;

fn spawn_worker() -> (String, Arc<Worker>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = Arc::new(Worker::new(WorkerConfig::default()));
    let w = worker.clone();
    std::thread::spawn(move || {
        let _ = w.serve(listener);
    });
    (addr, worker)
}

fn jobs(n: usize, n_in: usize, n_out: usize, rows: usize) -> Vec<LayerJob> {
    (0..n)
        .map(|i| LayerJob {
            name: format!("bench.l{i}"),
            problem: synthetic_problem(n_in, n_out, rows, 1000 + i as u64),
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mode = if smoke { " (smoke)" } else { "" };
    println!("== bench_sharded: distributed layer solves{mode} ==");

    // ---------------------------------------------- (a) engine throughput
    let (n_layers, n_in, n_out, rows) =
        if smoke { (6, 24, 12, 80) } else { (24, 64, 32, 256) };
    let alps_iters = if smoke { 40 } else { 150 };
    let spec = MethodSpec::Alps(AlpsConfig { max_iters: alps_iters, ..Default::default() });
    let target = SparsityTarget::Unstructured(0.7);
    let js = jobs(n_layers, n_in, n_out, rows);

    // reference: in-process native engine
    let native = NativeEngine::new(spec.clone());
    let t = Timer::start();
    let ref_results = native.solve_block(&js, target)?;
    let native_secs = t.elapsed_secs();

    let mut table = Table::new(&["engine", "layers", "secs", "layers/s", "bit-identical"]);
    table.row(&[
        "native".into(),
        n_layers.to_string(),
        format!("{native_secs:.3}"),
        format!("{:.1}", n_layers as f64 / native_secs),
        "-".into(),
    ]);

    for pool in [1usize, 2] {
        let workers: Vec<(String, Arc<Worker>)> = (0..pool).map(|_| spawn_worker()).collect();
        let addrs = workers.iter().map(|(a, _)| a.clone()).collect();
        let engine = ShardedEngine::with_config(
            spec.clone(),
            addrs,
            ShardedConfig::default(),
        )?;
        let t = Timer::start();
        let results = engine.solve_block(&js, target)?;
        let secs = t.elapsed_secs();
        let identical = results
            .iter()
            .zip(&ref_results)
            .all(|(r, l)| r.w == l.w);
        assert!(identical, "sharded({pool}) diverged from native — transport bug");
        table.row(&[
            format!("sharded x{pool}"),
            n_layers.to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", n_layers as f64 / secs),
            "yes".into(),
        ]);
        engine.close();
        for (_, w) in &workers {
            w.request_shutdown();
        }
    }
    table.print();

    // ------------------- (b) gram-on-coordinator vs gram-on-worker (wide)
    // wide-layer fixture: calibration rows < n_in, where shipping
    // X [rows, n_in] beats shipping H [n_in, n_in]
    let (wn_layers, wn_in, wn_out, wrows) =
        if smoke { (6, 48, 16, 20) } else { (16, 192, 64, 96) };
    assert!(wrows < wn_in, "fixture must be wide for the byte comparison");
    let wspec = MethodSpec::Alps(AlpsConfig {
        max_iters: if smoke { 30 } else { 100 },
        ..Default::default()
    });
    let wjs = jobs(wn_layers, wn_in, wn_out, wrows);

    // per-layer wire bytes, both encodings of the same request
    let p = &wjs[0].problem;
    let x = p.x.as_deref().expect("synthetic problems retain activations");
    let bytes_gram =
        encode_solve(0, target, &wspec, &p.what, CalibRef::Gram(&p.h)).len();
    let bytes_acts =
        encode_solve(0, target, &wspec, &p.what, CalibRef::Activations(x)).len();
    assert!(
        bytes_acts < bytes_gram,
        "activation shipping must cut wire bytes when rows < n_in \
         ({bytes_acts}B !< {bytes_gram}B)"
    );

    let w_native = NativeEngine::new(wspec.clone());
    let t = Timer::start();
    let w_ref = w_native.solve_block(&wjs, target)?;
    let w_native_secs = t.elapsed_secs();

    let mut wtable =
        Table::new(&["calibration", "bytes/layer", "secs", "layers/s", "bit-identical"]);
    wtable.row(&[
        "(native)".into(),
        "-".into(),
        format!("{w_native_secs:.3}"),
        format!("{:.1}", wn_layers as f64 / w_native_secs),
        "-".into(),
    ]);
    for ship in [false, true] {
        let (addr, worker) = spawn_worker();
        let engine = ShardedEngine::with_config(
            wspec.clone(),
            vec![addr],
            ShardedConfig { ship_activations: ship, ..Default::default() },
        )?;
        let t = Timer::start();
        let results = engine.solve_block(&wjs, target)?;
        let secs = t.elapsed_secs();
        let identical = results.iter().zip(&w_ref).all(|(r, l)| r.w == l.w);
        assert!(
            identical,
            "sharded (ship_activations={ship}) diverged from native — transport bug"
        );
        let calib_label =
            if ship { "activations (worker gram)" } else { "gram (coordinator)" };
        wtable.row(&[
            calib_label.to_string(),
            (if ship { bytes_acts } else { bytes_gram }).to_string(),
            format!("{secs:.3}"),
            format!("{:.1}", wn_layers as f64 / secs),
            "yes".into(),
        ]);
        engine.close();
        worker.request_shutdown();
    }
    wtable.print();
    println!(
        "wide fixture [{wn_in}x{wn_out}, {wrows} calib rows]: shipping activations moves \
         {bytes_gram}B -> {bytes_acts}B per layer ({:.1}x smaller)",
        bytes_gram as f64 / bytes_acts as f64
    );
    println!(
        "note: loopback workers share this machine's cores with the coordinator, so \
         pool>1 shows protocol overhead, not speedup; the win is one pool member per host."
    );
    Ok(())
}
