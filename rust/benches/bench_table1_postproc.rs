//! Table 1 (right): post-processing comparison on the MP support —
//! (i) no post-processing, (ii) ALPS's vectorized PCG (Algorithm 2),
//! (iii) exact per-column backsolve — error AND wall-clock, reproducing
//! the paper's 20x-200x PCG speedup claim.
//!
//!     cargo bench --bench bench_table1_postproc

use alps::bench::{bench, large_layer_problem};
use alps::config::SparsityTarget;
use alps::linalg::solve::pcg_support;
use alps::pruning::{backsolve, MethodSpec};
use alps::util::table::{fmt_sig, Table};

fn main() -> anyhow::Result<()> {
    let p = large_layer_problem()?;
    println!(
        "== Table 1 (right): post-processing on the MP support ({}x{}) ==\n",
        p.n_in(),
        p.n_out()
    );
    let mut table = Table::new(&[
        "sparsity",
        "w/o pp err",
        "PCG err",
        "PCG time(s)",
        "backsolve err",
        "backsolve time(s)",
        "speedup",
    ]);
    for s in [0.5f64, 0.6, 0.7, 0.8, 0.9] {
        let target = SparsityTarget::Unstructured(s);
        let w_mp = MethodSpec::Magnitude.prune(&p, target)?;
        let mask = w_mp.support_mask();
        let err_raw = p.rel_error(&w_mp);

        let pcg_stats = bench(1, 3, || {
            pcg_support(&p.h, &p.g, &w_mp, &mask, 10, 1e-12).0
        });
        let (w_pcg, _) = pcg_support(&p.h, &p.g, &w_mp, &mask, 10, 1e-12);
        let err_pcg = p.rel_error(&w_pcg);

        let bs_stats = bench(0, 1, || {
            backsolve::solve_on_support(&p, &mask).unwrap()
        });
        let w_bs = backsolve::solve_on_support(&p, &mask)?;
        let err_bs = p.rel_error(&w_bs);

        let speedup = bs_stats.median() / pcg_stats.median().max(1e-9);
        table.row(&[
            format!("{s:.1}"),
            fmt_sig(err_raw),
            fmt_sig(err_pcg),
            format!("{:.4}", pcg_stats.median()),
            fmt_sig(err_bs),
            format!("{:.3}", bs_stats.median()),
            format!("{speedup:.0}x"),
        ]);
    }
    table.print();
    println!(
        "\npaper shape: PCG error ~= backsolve error at a 20x-200x speedup\n\
         (paper: 0.77s vs 131s at s=0.5 on 5120x5120)."
    );
    Ok(())
}
