//! Minimal stand-in for the `anyhow` crate (the real registry is
//! unavailable in the sealed build environment). Covers exactly the API
//! surface this workspace uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Context chains are flattened into one message string ("ctx: cause")
//! instead of keeping a source chain — `Display` therefore shows the full
//! chain, which is what the CLI prints anyway.

use std::fmt;

/// String-backed error value; mirrors `anyhow::Error` for our purposes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: any std error converts via `?`. Sound because `Error`
// itself deliberately does NOT implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // include the first source level, the common case for io errors
        match e.source() {
            Some(src) => Error { msg: format!("{e}: {src}") },
            None => Error::msg(&e),
        }
    }
}

/// `anyhow::Result<T>` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse()?; // exercises From<ParseIntError>
        ensure!(n > 0, "want positive, got {n}");
        Ok(n)
    }

    #[test]
    fn conversion_and_ensure() {
        assert_eq!(parse("3").unwrap(), 3);
        assert!(parse("x").is_err());
        let e = parse("0").unwrap_err();
        assert_eq!(e.to_string(), "want positive, got 0");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn bail_formats() {
        fn f(x: i32) -> Result<()> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(())
        }
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        assert!(f(1).is_ok());
    }
}
