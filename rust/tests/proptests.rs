//! Property-based tests (hand-rolled harness: proptest is unavailable
//! offline). Each property runs across a sweep of PRNG seeds and
//! dimensions; failures print the seed for reproduction.

use alps::config::{AlpsConfig, SparsityTarget};
use alps::linalg::matmul::{gram, matmul};
use alps::linalg::solve::pcg_support;
use alps::linalg::{Cholesky, Matrix, SymEig};
use alps::pruning::alps::{rho_update, Alps, DiagScaling};
use alps::pruning::projection::{nm_project, topk_project};
use alps::pruning::{LayerProblem, PruneMethod};
use alps::util::Rng;

/// Run `prop` across seeds; panic with the failing seed.
fn for_seeds(n: u64, prop: impl Fn(u64)) {
    for seed in 0..n {
        prop(seed);
    }
}

fn rand_dims(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

// ---------------------------------------------------------------- topk

#[test]
fn prop_topk_exact_count_and_optimality() {
    for_seeds(40, |seed| {
        let mut rng = Rng::new(seed);
        let r = rand_dims(&mut rng, 1, 12);
        let c = rand_dims(&mut rng, 1, 12);
        let w = Matrix::randn(r, c, &mut rng);
        let k = rng.below(r * c + 1);
        let p = topk_project(&w, k);
        assert_eq!(p.nnz().min(k), p.nnz(), "seed {seed}: nnz > k");
        if k <= r * c {
            assert_eq!(p.nnz(), k.min(w.nnz()), "seed {seed}");
        }
        // kept magnitudes >= dropped magnitudes
        let kept_min = w
            .data
            .iter()
            .zip(&p.data)
            .filter(|(_, pv)| **pv != 0.0)
            .map(|(wv, _)| wv.abs())
            .fold(f32::INFINITY, f32::min);
        let dropped_max = w
            .data
            .iter()
            .zip(&p.data)
            .filter(|(wv, pv)| **pv == 0.0 && **wv != 0.0)
            .map(|(wv, _)| wv.abs())
            .fold(0.0, f32::max);
        if p.nnz() > 0 && p.nnz() < w.nnz() {
            assert!(kept_min >= dropped_max, "seed {seed}: {kept_min} < {dropped_max}");
        }
    });
}

#[test]
fn prop_nm_projection_budget_and_optimality() {
    for_seeds(40, |seed| {
        let mut rng = Rng::new(seed + 100);
        let m = if seed % 2 == 0 { 4 } else { 8 };
        let n = 1 + rng.below(m - 1);
        let groups = rand_dims(&mut rng, 1, 6);
        let cols = rand_dims(&mut rng, 1, 5);
        let w = Matrix::randn(groups * m, cols, &mut rng);
        let p = nm_project(&w, n, m);
        for c in 0..cols {
            for g0 in (0..groups * m).step_by(m) {
                let kept: Vec<f32> = (g0..g0 + m)
                    .filter(|&r| p.at(r, c) != 0.0)
                    .map(|r| w.at(r, c).abs())
                    .collect();
                assert!(kept.len() <= n, "seed {seed}");
                let dropped_max = (g0..g0 + m)
                    .filter(|&r| p.at(r, c) == 0.0)
                    .map(|r| w.at(r, c).abs())
                    .fold(0.0f32, f32::max);
                let kept_min = kept.iter().cloned().fold(f32::INFINITY, f32::min);
                if !kept.is_empty() && kept.len() == n {
                    assert!(kept_min >= dropped_max, "seed {seed}");
                }
            }
        }
    });
}

// ---------------------------------------------------------------- linalg

#[test]
fn prop_eigh_reconstructs_and_orthonormal() {
    for_seeds(15, |seed| {
        let mut rng = Rng::new(seed + 200);
        let n = rand_dims(&mut rng, 2, 24);
        let x = Matrix::randn(n + 5, n, &mut rng);
        let h = gram(&x);
        let e = SymEig::new(&h).unwrap();
        // Q diag Q^T == H
        let mut lam_qt = e.q.transpose();
        for i in 0..n {
            lam_qt.scale_row(i, e.vals[i]);
        }
        let rec = matmul(&e.q, &lam_qt);
        assert!(
            rec.sub(&h).fro_norm() / h.fro_norm().max(1.0) < 1e-3,
            "seed {seed}"
        );
        let qtq = matmul(&e.q.transpose(), &e.q);
        assert!(qtq.max_abs_diff(&Matrix::identity(n)) < 1e-3, "seed {seed}");
    });
}

#[test]
fn prop_cholesky_solve_residual() {
    for_seeds(20, |seed| {
        let mut rng = Rng::new(seed + 300);
        let n = rand_dims(&mut rng, 1, 20);
        let x = Matrix::randn(n + 6, n, &mut rng);
        let mut h = gram(&x);
        for i in 0..n {
            *h.at_mut(i, i) += 0.2;
        }
        let b: Vec<f32> = rng.gaussian_vec(n);
        let sol = Cholesky::new(&h).unwrap().solve_vec(&b);
        let hx = alps::linalg::matmul::matvec(&h, &sol);
        for i in 0..n {
            assert!((hx[i] - b[i]).abs() < 1e-2, "seed {seed} idx {i}");
        }
    });
}

#[test]
fn prop_pcg_objective_never_worse_than_start() {
    for_seeds(15, |seed| {
        let mut rng = Rng::new(seed + 400);
        let n = rand_dims(&mut rng, 4, 20);
        let m = rand_dims(&mut rng, 1, 8);
        let x = Matrix::randn(n + 10, n, &mut rng);
        let what = Matrix::randn(n, m, &mut rng);
        let p = LayerProblem::from_activations(&x, &what).unwrap();
        let k = 1 + rng.below(n * m);
        let w0 = topk_project(&what, k);
        let mask = w0.support_mask();
        let (w, _) = pcg_support(&p.h, &p.g, &w0, &mask, 10, 1e-12);
        assert!(
            p.rel_error(&w) <= p.rel_error(&w0) + 1e-6,
            "seed {seed}: PCG made things worse"
        );
    });
}

// ---------------------------------------------------------------- ADMM

#[test]
fn prop_alps_budget_and_finiteness() {
    for_seeds(10, |seed| {
        let mut rng = Rng::new(seed + 500);
        let n = rand_dims(&mut rng, 6, 20);
        let m = rand_dims(&mut rng, 2, 8);
        let x = Matrix::randn(n + 8, n, &mut rng);
        let what = Matrix::randn(n, m, &mut rng);
        let p = LayerProblem::from_activations(&x, &what).unwrap();
        let s = [0.3, 0.5, 0.7, 0.9][seed as usize % 4];
        let t = SparsityTarget::Unstructured(s);
        let w = Alps::default().prune(&p, t).unwrap();
        assert!(w.nnz() <= t.keep_count(n, m), "seed {seed}");
        assert!(w.data.iter().all(|v| v.is_finite()), "seed {seed}");
        assert!(p.rel_error(&w) <= 1.0 + 1e-6, "seed {seed}");
    });
}

#[test]
fn prop_theorem1_gap_bounded_by_c_over_rho() {
    // with a geometric rho schedule, gap(t) * rho(t) must stay bounded
    for_seeds(8, |seed| {
        let mut rng = Rng::new(seed + 600);
        let n = rand_dims(&mut rng, 8, 16);
        let m = rand_dims(&mut rng, 2, 6);
        let x = Matrix::randn(n + 8, n, &mut rng);
        let what = Matrix::randn(n, m, &mut rng);
        let p = LayerProblem::from_activations(&x, &what).unwrap();
        let (_, trace) = Alps::default()
            .prune_traced(&p, SparsityTarget::Unstructured(0.6))
            .unwrap();
        // primal gaps recorded at each rho checkpoint must shrink overall
        let gaps = &trace.primal_gaps;
        if gaps.len() >= 3 {
            let early = gaps[0].max(1e-12);
            let late = *gaps.last().unwrap();
            assert!(late <= early * 2.0, "seed {seed}: gap grew {early} -> {late}");
        }
    });
}

#[test]
fn prop_rho_update_monotone_nondecreasing() {
    let cfg = AlpsConfig::default();
    for_seeds(50, |seed| {
        let mut rng = Rng::new(seed + 700);
        let k = 1 + rng.below(10_000);
        let s_t = rng.below(k + 1);
        let rho = 0.01 + rng.uniform_f32() * 10.0;
        let new = rho_update(rho, s_t, k, &cfg);
        assert!(new >= rho, "seed {seed}");
        assert!(new <= rho * 1.3 + 1e-6, "seed {seed}");
    });
}

#[test]
fn prop_scaling_preserves_problem() {
    // solving the scaled problem and unscaling == solving the original:
    // check the objective value is invariant for any W
    for_seeds(15, |seed| {
        let mut rng = Rng::new(seed + 800);
        let n = rand_dims(&mut rng, 4, 16);
        let m = rand_dims(&mut rng, 2, 6);
        let x = Matrix::randn(n + 6, n, &mut rng);
        let what = Matrix::randn(n, m, &mut rng);
        let p = LayerProblem::from_activations(&x, &what).unwrap();
        let (scaling, hs) = DiagScaling::from_gram(&p.h, 0.0);
        let w = Matrix::randn(n, m, &mut rng);
        // (What - W)^T H (What - W) == (What' - W')^T H' (What' - W')
        let delta = p.what.sub(&w);
        let obj = delta.dot(&matmul(&p.h, &delta));
        let ws = scaling.to_scaled(&w);
        let whats = scaling.to_scaled(&p.what);
        let deltas = whats.sub(&ws);
        let objs = deltas.dot(&matmul(&hs, &deltas));
        assert!(
            (obj - objs).abs() / obj.abs().max(1e-6) < 1e-3,
            "seed {seed}: {obj} vs {objs}"
        );
    });
}

#[test]
fn prop_sparse_csr_roundtrip_random_density() {
    for_seeds(25, |seed| {
        let mut rng = Rng::new(seed + 900);
        let r = rand_dims(&mut rng, 1, 30);
        let c = rand_dims(&mut rng, 1, 30);
        let density = rng.uniform();
        let mut m = Matrix::zeros(r, c);
        for v in m.data.iter_mut() {
            if rng.uniform() < density {
                *v = rng.gaussian();
            }
        }
        let csr = alps::linalg::Csr::from_dense(&m);
        assert_eq!(csr.to_dense(), m, "seed {seed}");
    });
}
