//! Runtime integration: the AOT HLO artifacts must reproduce the native
//! rust math — ADMM iteration parity, PCG parity, model-forward parity,
//! and the pallas-kernel variant. Skipped (with a notice) when artifacts
//! have not been built.

use alps::config::{AlpsConfig, SparsityTarget};
use alps::linalg::matmul::gram;
use alps::linalg::Matrix;
use alps::model::Model;
use alps::pruning::alps::Alps;
use alps::pruning::LayerProblem;
use alps::runtime::executor::{gram_via_runtime, AlpsHlo, ModelFwdHlo};
use alps::runtime::Runtime;
use alps::util::Rng;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn problem_128() -> LayerProblem {
    let mut rng = Rng::new(0);
    let mut x = Matrix::randn(300, 128, &mut rng);
    for c in 0..128 {
        let s = 0.3 + 1.5 * (c as f32 / 128.0);
        for r in 0..300 {
            *x.at_mut(r, c) *= s;
        }
    }
    let what = Matrix::randn(128, 128, &mut rng);
    LayerProblem::from_activations(&x, &what).unwrap()
}

#[test]
fn hlo_alps_matches_native_alps() {
    let Some(rt) = runtime() else { return };
    let p = problem_128();
    let t = SparsityTarget::Unstructured(0.7);
    let hlo = AlpsHlo::new(&rt);
    assert!(hlo.supports(128, 128, t));
    let (w_hlo, trace_hlo) = hlo.prune_traced(&p, t).unwrap();
    let (w_nat, trace_nat) = Alps::default().prune_traced(&p, t).unwrap();
    let (e_hlo, e_nat) = (p.rel_error(&w_hlo), p.rel_error(&w_nat));
    // identical algorithm, different substrates: errors must agree closely
    assert!(
        (e_hlo - e_nat).abs() / e_nat.max(1e-9) < 0.05,
        "hlo {e_hlo} vs native {e_nat}"
    );
    // same ballpark of iterations
    let (a, b) = (trace_hlo.admm_iters as f64, trace_nat.admm_iters as f64);
    assert!(a / b < 2.0 && b / a < 2.0, "iters {a} vs {b}");
    // budget respected
    assert!(w_hlo.nnz() <= t.keep_count(128, 128));
}

#[test]
fn hlo_alps_nm_pattern() {
    let Some(rt) = runtime() else { return };
    // N:M artifacts exist for alps-base shapes (256x256 etc.)
    let mut rng = Rng::new(1);
    let x = Matrix::randn(400, 256, &mut rng);
    let what = Matrix::randn(256, 256, &mut rng);
    let p = LayerProblem::from_activations(&x, &what).unwrap();
    let t = SparsityTarget::NM { n: 2, m: 4 };
    let hlo = AlpsHlo::new(&rt);
    assert!(hlo.supports(256, 256, t));
    let (w, _) = hlo.prune_traced(&p, t).unwrap();
    assert!(alps::pruning::check_target(&w, t));
    let e_alps = p.rel_error(&w);
    let w_mp = alps::pruning::projection::nm_project(&what, 2, 4);
    assert!(e_alps < p.rel_error(&w_mp));
}

#[test]
fn gram_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    // gram artifact shape: rows=4096, n_in=128, n_out=128 (alps-tiny attn)
    let x = Matrix::randn(4096, 128, &mut rng);
    let what = Matrix::randn(128, 128, &mut rng);
    let (h_rt, g_rt) = gram_via_runtime(&rt, &x, &what).unwrap();
    let h = gram(&x);
    let g = alps::linalg::matmul::matmul(&h, &what);
    assert!(h_rt.max_abs_diff(&h) / h.fro_norm() < 1e-4);
    assert!(g_rt.max_abs_diff(&g) / g.fro_norm() < 1e-4);
}

#[test]
fn pallas_variant_matches_jnp_variant() {
    let Some(rt) = runtime() else { return };
    if !rt.has("admm_iter_pallas_128x128") {
        eprintln!("SKIP: pallas variant not exported");
        return;
    }
    use alps::runtime::client::Value;
    let p = problem_128();
    let eig = alps::linalg::SymEig::new(&p.h).unwrap();
    let inputs = vec![
        Value::matrix(&eig.q),
        Value::vector(&eig.vals),
        Value::matrix(&p.g),
        Value::matrix(&p.what),
        Value::matrix(&Matrix::zeros(128, 128)),
        Value::scalar(1.0),
        Value::I32(5000),
    ];
    let out_a = rt.run("admm_iter_pallas_128x128", &inputs).unwrap();
    let out_b = rt.run("admm_iter_128x128", &inputs).unwrap();
    let wa = out_a[0].clone().into_matrix(128, 128).unwrap();
    let wb = out_b[0].clone().into_matrix(128, 128).unwrap();
    assert!(
        wa.max_abs_diff(&wb) < 1e-2 * wb.fro_norm().max(1.0),
        "pallas vs jnp W-update diverge: {}",
        wa.max_abs_diff(&wb)
    );
    // D outputs: identical supports
    let da = out_a[1].clone().into_matrix(128, 128).unwrap();
    let db = out_b[1].clone().into_matrix(128, 128).unwrap();
    assert_eq!(da.nnz(), db.nnz());
}

#[test]
fn model_fwd_artifact_matches_rust_forward() {
    let Some(rt) = runtime() else { return };
    let dir = Path::new("artifacts");
    if !dir.join("model_alps-tiny.bin").exists() {
        eprintln!("SKIP: models not built");
        return;
    }
    let model = Model::load(dir, "alps-tiny").unwrap();
    let fwd = ModelFwdHlo::new(&rt, &model).unwrap();
    let mut rng = Rng::new(3);
    let seqs: Vec<Vec<u16>> = (0..3)
        .map(|_| (0..128).map(|_| rng.below(293) as u16).collect())
        .collect();
    let nll_hlo = fwd.nll_batch(&seqs).unwrap();
    assert_eq!(nll_hlo.len(), 3);
    for (seq, hlo_row) in seqs.iter().zip(&nll_hlo) {
        let nll_native = model.nll(seq).unwrap();
        assert_eq!(hlo_row.len(), nll_native.len());
        let mean_diff: f64 = hlo_row
            .iter()
            .zip(&nll_native)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / hlo_row.len() as f64;
        assert!(mean_diff < 5e-3, "mean nll diff {mean_diff}");
    }
}

#[test]
fn runtime_validates_inputs() {
    let Some(rt) = runtime() else { return };
    use alps::runtime::client::Value;
    // wrong arity
    assert!(rt.run("admm_iter_128x128", &[]).is_err());
    // wrong shapes
    let bad = vec![
        Value::matrix(&Matrix::zeros(4, 4)),
        Value::vector(&[0.0; 4]),
        Value::matrix(&Matrix::zeros(4, 4)),
        Value::matrix(&Matrix::zeros(4, 4)),
        Value::matrix(&Matrix::zeros(4, 4)),
        Value::scalar(1.0),
        Value::I32(4),
    ];
    assert!(rt.run("admm_iter_128x128", &bad).is_err());
    // unknown artifact
    assert!(rt.run("nonexistent", &[]).is_err());
}

#[test]
fn executable_cache_reused() {
    let Some(rt) = runtime() else { return };
    use alps::runtime::client::Value;
    let p = problem_128();
    let eig = alps::linalg::SymEig::new(&p.h).unwrap();
    let inputs = vec![
        Value::matrix(&eig.q),
        Value::vector(&eig.vals),
        Value::matrix(&p.g),
        Value::matrix(&p.what),
        Value::matrix(&Matrix::zeros(128, 128)),
        Value::scalar(0.5),
        Value::I32(1000),
    ];
    rt.run("admm_iter_128x128", &inputs).unwrap();
    rt.run("admm_iter_128x128", &inputs).unwrap();
    assert_eq!(rt.exec_counts.borrow()["admm_iter_128x128"], 2);
}
