//! Cross-method integration tests on synthetic layer problems: the
//! paper-ordering invariants (Fig. 2 / Table 1 shapes) at several
//! sparsities, N:M patterns, and support-quality ablations.

use alps::config::SparsityTarget;
use alps::linalg::Matrix;
use alps::pruning::{
    alps::Alps, backsolve, dsnot::DsNoT, magnitude::MagnitudePruning,
    sparsegpt::SparseGpt, wanda::Wanda, LayerProblem, MethodSpec, PruneMethod,
};
use alps::util::Rng;

fn problem(n_in: usize, n_out: usize, rows: usize, seed: u64) -> LayerProblem {
    let mut rng = Rng::new(seed);
    let mut x = Matrix::randn(rows, n_in, &mut rng);
    for c in 0..n_in {
        let s = 0.25 + 2.0 * ((c * 31 % n_in) as f32 / n_in as f32);
        for r in 0..rows {
            *x.at_mut(r, c) *= s;
        }
    }
    let what = Matrix::randn(n_in, n_out, &mut rng);
    LayerProblem::from_activations(&x, &what).unwrap()
}

#[test]
fn fig2_shape_alps_wins_and_gap_widens() {
    let p = problem(48, 24, 160, 0);
    let mut gap_low = 0.0;
    let mut gap_high = 0.0;
    for (i, s) in [0.5f64, 0.8].iter().enumerate() {
        let t = SparsityTarget::Unstructured(*s);
        let e_alps = p.rel_error(&Alps::default().prune(&p, t).unwrap());
        let e_mp = p.rel_error(&MagnitudePruning.prune(&p, t).unwrap());
        assert!(e_alps < e_mp, "s={s}: alps {e_alps} !< mp {e_mp}");
        let gap = e_mp / e_alps.max(1e-12);
        if i == 0 {
            gap_low = gap;
        } else {
            gap_high = gap;
        }
    }
    // paper: the advantage persists (and typically grows) with sparsity.
    // On tiny synthetic layers the exact ratio is noisy, so require a
    // substantial margin at high sparsity rather than strict growth.
    assert!(
        gap_high > 1.3,
        "ALPS margin at high sparsity too small: low {gap_low:.2} high {gap_high:.2}"
    );
}

#[test]
fn table1_left_support_quality() {
    // fix each method's support, solve (6) optimally, compare errors:
    // ALPS support must be at least as good as MP/Wanda supports
    let p = problem(40, 20, 140, 1);
    let t = SparsityTarget::Unstructured(0.7);
    let err_on_support = |w: &Matrix| {
        let mask = w.support_mask();
        let opt = backsolve::solve_on_support(&p, &mask).unwrap();
        p.rel_error(&opt)
    };
    let e_alps = err_on_support(&Alps::default().prune(&p, t).unwrap());
    let e_mp = err_on_support(&MagnitudePruning.prune(&p, t).unwrap());
    let e_wanda = err_on_support(&Wanda.prune(&p, t).unwrap());
    assert!(e_alps <= e_mp * 1.02, "alps support {e_alps} vs mp {e_mp}");
    assert!(e_alps <= e_wanda * 1.02, "alps support {e_alps} vs wanda {e_wanda}");
}

#[test]
fn table1_right_pcg_matches_backsolve() {
    // MP support; refine with ALPS's PCG vs exact backsolve: errors close
    let p = problem(32, 16, 120, 2);
    let t = SparsityTarget::Unstructured(0.6);
    let w_mp = MagnitudePruning.prune(&p, t).unwrap();
    let mask = w_mp.support_mask();
    let w_bs = backsolve::solve_on_support_damped(&p, &mask, 0.0).unwrap();
    let (w_pcg, _) = alps::linalg::solve::pcg_support(
        &p.h, &p.g, &w_mp, &mask, 10, 1e-12,
    );
    let (e_bs, e_pcg, e_mp) =
        (p.rel_error(&w_bs), p.rel_error(&w_pcg), p.rel_error(&w_mp));
    assert!(e_bs <= e_pcg + 1e-9);
    assert!(e_pcg < e_mp, "refinement must help: {e_pcg} vs {e_mp}");
    assert!(
        (e_pcg - e_bs) / e_bs.max(1e-12) < 0.25,
        "pcg {e_pcg} far from backsolve {e_bs}"
    );
}

#[test]
fn all_methods_respect_nm_patterns() {
    let p = problem(32, 8, 100, 3);
    for (n, m) in [(2usize, 4usize), (4, 8)] {
        let t = SparsityTarget::NM { n, m };
        for spec in MethodSpec::all() {
            let w = spec.prune(&p, t).unwrap();
            assert!(
                alps::pruning::check_target(&w, t),
                "{} violates {n}:{m}",
                spec.label()
            );
        }
    }
}

#[test]
fn nm_alps_beats_nm_mp() {
    let p = problem(32, 16, 120, 4);
    let t = SparsityTarget::NM { n: 2, m: 4 };
    let e_alps = p.rel_error(&Alps::default().prune(&p, t).unwrap());
    let e_mp = p.rel_error(&MagnitudePruning.prune(&p, t).unwrap());
    assert!(e_alps < e_mp, "nm: alps {e_alps} !< mp {e_mp}");
}

#[test]
fn methods_monotone_in_sparsity() {
    let p = problem(24, 12, 90, 5);
    for name in ["mp", "wanda", "sparsegpt", "alps"] {
        let method = MethodSpec::parse(name).unwrap().build();
        let mut prev = -1.0f64;
        for s in [0.4, 0.6, 0.8] {
            let w = method.prune(&p, SparsityTarget::Unstructured(s)).unwrap();
            let e = p.rel_error(&w);
            assert!(
                e >= prev - 0.01,
                "{name}: error at {s} ({e}) below previous ({prev})"
            );
            prev = e;
        }
    }
}

#[test]
fn dsnot_improves_initial_mask() {
    let p = problem(28, 14, 100, 6);
    let t = SparsityTarget::Unstructured(0.65);
    let e_wanda = p.rel_error(&Wanda.prune(&p, t).unwrap());
    let e_dsnot = p.rel_error(&DsNoT::default().prune(&p, t).unwrap());
    assert!(e_dsnot <= e_wanda + 1e-9);
}

#[test]
fn sparsegpt_between_wanda_and_alps_typically() {
    // statistical claim over a few seeds: ALPS <= SparseGPT on average
    let mut alps_sum = 0.0;
    let mut sg_sum = 0.0;
    for seed in 10..14 {
        let p = problem(32, 16, 110, seed);
        let t = SparsityTarget::Unstructured(0.7);
        alps_sum += p.rel_error(&Alps::default().prune(&p, t).unwrap());
        sg_sum += p.rel_error(&SparseGpt::default().prune(&p, t).unwrap());
    }
    assert!(alps_sum < sg_sum, "alps {alps_sum} !< sparsegpt {sg_sum}");
}

#[test]
fn near_degenerate_gram_handled() {
    // rows < n_in: rank-deficient H; damping must keep everything finite
    let mut rng = Rng::new(20);
    let x = Matrix::randn(10, 24, &mut rng);
    let what = Matrix::randn(24, 8, &mut rng);
    let p = LayerProblem::from_activations(&x, &what).unwrap();
    for spec in MethodSpec::all() {
        let w = spec.prune(&p, SparsityTarget::Unstructured(0.5)).unwrap();
        assert!(
            w.data.iter().all(|v| v.is_finite()),
            "{} produced NaN/inf",
            spec.label()
        );
    }
}

#[test]
fn unknown_method_error_path() {
    // regression for the old validate-then-rediscard flow in cmd_prune:
    // MethodSpec::parse is now the single authority on method names, and
    // its error names the valid choices
    let err = MethodSpec::parse("not-a-method").unwrap_err().to_string();
    assert!(err.contains("unknown method 'not-a-method'"), "{err}");
    for valid in ["mp", "wanda", "sparsegpt", "dsnot", "alps", "alps-struct"] {
        assert!(err.contains(valid), "error should list '{valid}': {err}");
    }
}

#[test]
fn checkpoint_resume_round_trip_public_api() {
    // the acceptance-criteria round trip, entirely through the public API:
    // an interrupted-then-resumed run must be bit-identical to an
    // uninterrupted one
    use alps::config::ModelConfig;
    use alps::model::Model;
    use alps::pruning::PruneSession;

    let cfg = ModelConfig {
        name: "roundtrip".into(),
        d_model: 16,
        d_ff: 32,
        n_layers: 3,
        n_heads: 4,
        vocab: 24,
        seq_len: 12,
    };
    let mut rng = Rng::new(0x5E55);
    let calib: Vec<Vec<u16>> = (0..4)
        .map(|_| (0..8).map(|_| rng.below(24) as u16).collect())
        .collect();
    let target = SparsityTarget::Unstructured(0.6);
    // sparsegpt compensates errors through the gram, so block k+1 depends
    // on block k's pruned weights — a wrong resume point would diverge
    let spec = MethodSpec::parse("sparsegpt").unwrap();

    let mut m_ref = Model::random(cfg.clone(), 99).unwrap();
    PruneSession::builder()
        .calib(calib.clone())
        .target(target)
        .method(spec.clone())
        .run(&mut m_ref)
        .unwrap();

    let dir = std::env::temp_dir().join("alps_it_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let mut m_cut = Model::random(cfg.clone(), 99).unwrap();
    PruneSession::builder()
        .calib(calib.clone())
        .target(target)
        .method(spec.clone())
        .checkpoint_dir(&dir)
        .stop_after(2)
        .run(&mut m_cut)
        .unwrap();

    let mut m_res = Model::random(cfg, 99).unwrap();
    let report = PruneSession::builder()
        .calib(calib)
        .target(target)
        .method(spec)
        .checkpoint_dir(&dir)
        .resume(true)
        .run(&mut m_res)
        .unwrap();

    assert_eq!(report.layers.len(), 3 * 6, "resumed report covers all layers");
    for (name, t_ref) in &m_ref.weights.tensors {
        let t_res = m_res.weights.tensors.get(name).unwrap();
        assert_eq!(
            t_ref.data, t_res.data,
            "tensor '{name}' not bit-identical after resume"
        );
    }
}
