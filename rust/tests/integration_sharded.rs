//! Distributed pruning integration: a worker pool and a coordinator in
//! one process over 127.0.0.1, proving the acceptance criteria —
//! a [`ShardedEngine`] run is **bit-identical** to a [`NativeEngine`]
//! run for the same `MethodSpec` (with grams computed on either side of
//! the wire), a dropped or silent worker's layers are rerouted (within
//! the heartbeat grace, not the idle timeout) and the run still
//! completes, the persistent pool reuses connections across blocks,
//! membership can churn mid-run (workers killed, replacements joining
//! through the REGISTER handshake) without perturbing a bit, and the
//! status endpoint reports per-worker attribution.

use alps::config::{AlpsConfig, ModelConfig, SparsityTarget};
use alps::coordinator::{ShardedConfig, ShardedEngine};
use alps::model::Model;
use alps::net::framing::{read_frame, write_frame, FrameRead};
use alps::pruning::wire::{self, tag};
use alps::pruning::worker::{Worker, WorkerConfig};
use alps::pruning::{
    Engine, LayerJob, LayerProblem, MethodSpec, NativeEngine, PruneSession, StatusBoard,
    StatusServer,
};
use alps::util::Rng;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

fn tiny_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        d_model: 16,
        d_ff: 32,
        n_layers: 2,
        n_heads: 4,
        vocab: 24,
        seq_len: 12,
    }
}

fn calib_seqs(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect())
        .collect()
}

fn quick_cfg() -> ShardedConfig {
    ShardedConfig {
        max_attempts: 2,
        connect_timeout: Duration::from_secs(1),
        idle_timeout: Duration::from_secs(60),
        retry_backoff: Duration::from_millis(10),
        ..Default::default()
    }
}

fn random_problems(n: usize, seed: u64) -> Vec<LayerJob> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut x = alps::linalg::Matrix::randn(50, 14, &mut rng);
            for c in 0..14 {
                let s = 0.3 + 1.5 * (c as f32 / 14.0);
                for r in 0..50 {
                    *x.at_mut(r, c) *= s;
                }
            }
            let what = alps::linalg::Matrix::randn(14, 7, &mut rng);
            LayerJob {
                name: format!("blocks.0.l{i}"),
                problem: LayerProblem::from_activations(&x, &what).unwrap(),
            }
        })
        .collect()
}

/// Session-level proof for the acceptance criterion: pruning a model
/// through a loopback worker pool is bit-identical to the native engine,
/// for both ALPS (the paper's method) and SparseGPT (whose block k+1
/// depends on block k's pruned weights through the gram — a wrong
/// reassembly or a perturbed bit would diverge here).
#[test]
fn sharded_session_bit_identical_to_native_for_alps_and_sparsegpt() {
    let calib = calib_seqs(4, 8, 24, 11);
    let target = SparsityTarget::Unstructured(0.6);
    let specs = [
        MethodSpec::Alps(AlpsConfig { max_iters: 80, ..Default::default() }),
        MethodSpec::SparseGpt(Default::default()),
    ];
    for (si, spec) in specs.into_iter().enumerate() {
        let mut m_native = Model::random(tiny_cfg("shard-bitident"), 77).unwrap();
        let mut m_sharded = Model::random(tiny_cfg("shard-bitident"), 77).unwrap();

        PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .method(spec.clone())
            .run(&mut m_native)
            .unwrap();

        // two workers so reassembly order is genuinely exercised
        let workers: Vec<(String, std::sync::Arc<Worker>)> = (0..2)
            .map(|_| {
                let listener = TcpListener::bind("127.0.0.1:0").unwrap();
                let addr = listener.local_addr().unwrap().to_string();
                let worker = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
                let w = worker.clone();
                std::thread::spawn(move || {
                    let _ = w.serve(listener);
                });
                (addr, worker)
            })
            .collect();
        let addrs: Vec<String> = workers.iter().map(|(a, _)| a.clone()).collect();
        let engine =
            ShardedEngine::with_config(spec.clone(), addrs, quick_cfg()).unwrap();
        let report = PruneSession::builder()
            .calib(calib.clone())
            .target(target)
            .engine(Box::new(engine))
            .run(&mut m_sharded)
            .unwrap();
        assert_eq!(report.method, format!("sharded({})", spec.label()));

        for (name, t_native) in &m_native.weights.tensors {
            let t_sharded = m_sharded.weights.tensors.get(name).unwrap();
            let bits_n: Vec<u32> = t_native.data.iter().map(|v| v.to_bits()).collect();
            let bits_s: Vec<u32> = t_sharded.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                bits_n, bits_s,
                "spec #{si}: tensor '{name}' not bit-identical to native"
            );
        }
        for (_, w) in &workers {
            w.request_shutdown();
        }
        // both workers must have contributed (the pool really sharded)
        let solved: usize = workers.iter().map(|(_, w)| w.layers_solved()).sum();
        assert!(solved >= 12, "pool solved {solved} layers, expected a full run");
    }
}

/// Worker-drop resilience: a pool where one member dies mid-solve (after
/// accepting a job) and another was never reachable still completes, with
/// results bit-identical to native — the dropped member's in-flight layer
/// is rerouted to the survivor.
#[test]
fn worker_drop_reroutes_layers_and_run_completes() {
    let jobs = random_problems(6, 21);
    let target = SparsityTarget::Unstructured(0.55);
    let spec = MethodSpec::Wanda;

    // live worker
    let live_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let live_addr = live_listener.local_addr().unwrap().to_string();
    let live = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
    let live2 = live.clone();
    std::thread::spawn(move || {
        let _ = live2.serve(live_listener);
    });

    // saboteur: accepts one connection, swallows one solve request, then
    // drops the connection and the listener — an in-flight layer is lost
    // mid-solve and later reconnects are refused outright
    let sab_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let sab_addr = sab_listener.local_addr().unwrap().to_string();
    let saboteur = std::thread::spawn(move || {
        // bounded accept wait: if the survivor drains the queue before the
        // coordinator ever dials us, give up instead of blocking the join
        sab_listener.set_nonblocking(true).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match sab_listener.accept() {
                Ok((mut conn, _)) => {
                    let _ = conn.set_nonblocking(false);
                    let _ = conn.set_read_timeout(Some(Duration::from_millis(200)));
                    let _ =
                        read_frame(&mut conn, 1 << 30, None, Some(Duration::from_secs(10)));
                    break; // conn drops: the accepted job is lost mid-solve
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if std::time::Instant::now() > deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        } // listener drops: reconnects are refused
    });

    // unreachable: bound then immediately released port
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };

    let engine = ShardedEngine::with_config(
        spec.clone(),
        vec![sab_addr, dead_addr, live_addr.clone()],
        quick_cfg(),
    )
    .unwrap();
    let remote = engine.solve_block(&jobs, target).unwrap();
    let local = NativeEngine::new(spec).solve_block(&jobs, target).unwrap();
    assert_eq!(remote.len(), jobs.len());
    for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
        assert_eq!(r.w, l.w, "layer {i} differs after rerouting");
        // every surviving solve is attributed to the live worker
        assert_eq!(r.worker.as_deref(), Some(live_addr.as_str()), "layer {i}");
    }
    assert_eq!(live.layers_solved(), jobs.len(), "survivor solved everything");
    saboteur.join().unwrap();
    live.request_shutdown();
}

/// Keepalive reroute (the heartbeat acceptance criterion): a saboteur
/// accepts a job then goes **silent mid-solve** — the connection stays
/// open, so only missed heartbeats can expose it. With an idle timeout of
/// an hour and a sub-second heartbeat grace, the run must reroute to the
/// live worker and finish bit-identically in seconds, not hours.
#[test]
fn silent_worker_detected_by_missed_heartbeats_not_idle_timeout() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let jobs = random_problems(6, 61);
    let target = SparsityTarget::Unstructured(0.6);
    let spec = MethodSpec::Wanda;

    // live worker with a fast beat, comfortably inside the grace
    let live_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let live_addr = live_listener.local_addr().unwrap().to_string();
    let live = Arc::new(Worker::new(WorkerConfig {
        heartbeat_every: Duration::from_millis(100),
        ..Default::default()
    }));
    let live2 = live.clone();
    std::thread::spawn(move || {
        let _ = live2.serve(live_listener);
    });

    // saboteur: accepts every (re)dial, swallows one solve request, then
    // holds the connection open in silence — no EOF, no frames, nothing
    let sab_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let sab_addr = sab_listener.local_addr().unwrap().to_string();
    let done = Arc::new(AtomicBool::new(false));
    let done2 = done.clone();
    let saboteur = std::thread::spawn(move || {
        sab_listener.set_nonblocking(true).unwrap();
        let mut parked: Vec<TcpStream> = Vec::new();
        while !done2.load(Ordering::SeqCst) {
            match sab_listener.accept() {
                Ok((mut conn, _)) => {
                    let _ = conn.set_nonblocking(false);
                    let _ = conn.set_read_timeout(Some(Duration::from_millis(100)));
                    let _ =
                        read_frame(&mut conn, 1 << 30, None, Some(Duration::from_secs(5)));
                    parked.push(conn); // held open, silent
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    let started = std::time::Instant::now();
    let engine = ShardedEngine::with_config(
        spec.clone(),
        vec![sab_addr, live_addr.clone()],
        ShardedConfig {
            max_attempts: 2,
            connect_timeout: Duration::from_secs(1),
            // the point of the test: silence detection must come from the
            // heartbeat grace, with the idle ceiling out of reach
            idle_timeout: Duration::from_secs(3600),
            heartbeat_grace: Duration::from_millis(700),
            retry_backoff: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    let remote = engine.solve_block(&jobs, target).unwrap();
    let elapsed = started.elapsed();
    done.store(true, Ordering::SeqCst);

    let local = NativeEngine::new(spec).solve_block(&jobs, target).unwrap();
    for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
        assert_eq!(r.w, l.w, "layer {i} differs after heartbeat reroute");
        assert_eq!(r.worker.as_deref(), Some(live_addr.as_str()), "layer {i}");
    }
    // two grace windows (+ slack for loaded CI) — nowhere near the hour
    // the idle timeout would have cost
    assert!(
        elapsed < Duration::from_secs(120),
        "reroute took {elapsed:?}; heartbeat grace not in effect"
    );
    saboteur.join().unwrap();
    live.request_shutdown();
}

/// The flip side of the keepalive: a worker that is merely *slow* — it
/// stalls far past the heartbeat grace but keeps beating — must NOT be
/// rerouted. With `max_attempts: 1` and no other pool member, any false
/// positive fails the run.
#[test]
fn slow_but_beating_worker_is_not_rerouted() {
    let jobs = random_problems(2, 71);
    let target = SparsityTarget::Unstructured(0.55);
    let spec = MethodSpec::Wanda;
    let grace = Duration::from_millis(500);

    // a hand-rolled worker that sits on each request for 4 grace windows,
    // heartbeating, before solving it for real (bit-identically)
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let slow = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        conn.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let mut served = 0usize;
        while served < 2 {
            let req = match read_frame(&mut conn, 1 << 30, None, Some(Duration::from_secs(30)))
            {
                Ok(FrameRead::Frame { tag: tag::SOLVE, payload }) => {
                    wire::SolveRequest::decode(&payload).unwrap()
                }
                other => panic!("expected a solve frame, got {:?}", other.is_ok()),
            };
            let stall_until = std::time::Instant::now() + 4 * grace;
            while std::time::Instant::now() < stall_until {
                let beat = wire::encode_heartbeat(wire::Heartbeat {
                    job: req.job,
                    admm_iter: 0,
                    elapsed_ms: 1,
                });
                write_frame(&mut conn, tag::HEARTBEAT, &beat).unwrap();
                std::thread::sleep(Duration::from_millis(100));
            }
            let problem = req.problem().unwrap();
            let res = NativeEngine::new(req.spec.clone())
                .solve_layer(&problem, req.target)
                .unwrap();
            let resp = wire::SolveResponse {
                job: req.job,
                secs: res.secs,
                admm_iters: res.admm_iters as u64,
                w: res.w,
            };
            write_frame(&mut conn, tag::RESULT, &resp.encode()).unwrap();
            served += 1;
        }
    });

    let engine = ShardedEngine::with_config(
        spec.clone(),
        vec![addr.clone()],
        ShardedConfig {
            max_attempts: 1, // any false reroute is fatal
            max_outstanding: 1,
            connect_timeout: Duration::from_secs(1),
            idle_timeout: Duration::from_secs(3600),
            heartbeat_grace: grace,
            retry_backoff: Duration::from_millis(10),
            ..Default::default()
        },
    )
    .unwrap();
    let remote = engine.solve_block(&jobs, target).unwrap();
    let local = NativeEngine::new(spec).solve_block(&jobs, target).unwrap();
    for (i, (r, l)) in remote.iter().zip(&local).enumerate() {
        assert_eq!(r.w, l.w, "layer {i}");
        assert_eq!(r.worker.as_deref(), Some(addr.as_str()));
    }
    slow.join().unwrap();
}

/// Persistent pool + activation shipping at the session level: a
/// multi-block run over one engine dials each worker once (connections
/// are parked between blocks), ships X instead of the gram, and still
/// lands bit-identically on the native result.
#[test]
fn persistent_pool_ships_activations_across_blocks_bit_identically() {
    // one 8-token calibration sequence: 8 activation rows < n_in (16/32),
    // so every layer genuinely takes the activation-shipping encoding
    let calib = calib_seqs(1, 8, 24, 51);
    let target = SparsityTarget::Unstructured(0.6);
    let spec = MethodSpec::Alps(AlpsConfig { max_iters: 60, ..Default::default() });

    let mut m_native = Model::random(tiny_cfg("shard-persist"), 99).unwrap();
    PruneSession::builder()
        .calib(calib.clone())
        .target(target)
        .method(spec.clone())
        .run(&mut m_native)
        .unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
    let w2 = worker.clone();
    std::thread::spawn(move || {
        let _ = w2.serve(listener);
    });
    let engine = ShardedEngine::with_config(
        spec,
        vec![addr],
        ShardedConfig { ship_activations: true, ..quick_cfg() },
    )
    .unwrap();
    let mut m_sharded = Model::random(tiny_cfg("shard-persist"), 99).unwrap();
    PruneSession::builder()
        .calib(calib)
        .target(target)
        .engine(Box::new(engine))
        .run(&mut m_sharded)
        .unwrap();

    for (name, t_native) in &m_native.weights.tensors {
        let t_sharded = m_sharded.weights.tensors.get(name).unwrap();
        let bits_n: Vec<u32> = t_native.data.iter().map(|v| v.to_bits()).collect();
        let bits_s: Vec<u32> = t_sharded.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(bits_n, bits_s, "tensor '{name}' differs with shipped activations");
    }
    // a 2-block run = 2 solve_block calls; the parked connection must
    // have been reused, and the session's engine.close() released it
    assert_eq!(
        worker.connections_accepted(),
        1,
        "persistent pool dialed more than once across blocks"
    );
    assert_eq!(worker.layers_solved(), 12);
    worker.request_shutdown();
}

/// A checkpoint written by a native run resumes under a sharded engine
/// (same solver config => same config digest => same bits), and the
/// finished weights equal an uninterrupted native run exactly.
#[test]
fn native_checkpoint_resumes_on_sharded_engine_bit_identically() {
    let calib = calib_seqs(4, 8, 24, 41);
    let target = SparsityTarget::Unstructured(0.6);
    let spec = MethodSpec::Wanda;

    // uninterrupted native reference
    let mut m_ref = Model::random(tiny_cfg("shard-resume"), 88).unwrap();
    PruneSession::builder()
        .calib(calib.clone())
        .target(target)
        .method(spec.clone())
        .run(&mut m_ref)
        .unwrap();

    // native run "crashes" after block 0
    let dir = std::env::temp_dir().join("alps_sharded_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let mut m_cut = Model::random(tiny_cfg("shard-resume"), 88).unwrap();
    PruneSession::builder()
        .calib(calib.clone())
        .target(target)
        .method(spec.clone())
        .checkpoint_dir(&dir)
        .stop_after(1)
        .run(&mut m_cut)
        .unwrap();

    // resume the same checkpoint over a worker pool
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
    let w2 = worker.clone();
    std::thread::spawn(move || {
        let _ = w2.serve(listener);
    });
    let engine = ShardedEngine::with_config(spec, vec![addr], quick_cfg()).unwrap();
    let mut m_res = Model::random(tiny_cfg("shard-resume"), 88).unwrap();
    PruneSession::builder()
        .calib(calib)
        .target(target)
        .engine(Box::new(engine))
        .checkpoint_dir(&dir)
        .resume(true)
        .run(&mut m_res)
        .unwrap();
    worker.request_shutdown();

    for (name, t_ref) in &m_ref.weights.tensors {
        let t_res = m_res.weights.tensors.get(name).unwrap();
        assert_eq!(
            t_ref.data, t_res.data,
            "tensor '{name}' differs after native->sharded resume"
        );
    }
}

/// The dynamic-membership acceptance criterion: both seed workers are
/// killed mid-run and a fresh worker joins through the REGISTER
/// handshake — the run completes bit-identically to native, every
/// post-churn layer lands on the replacement, and the status board
/// records the full join/leave history.
#[test]
fn killed_workers_and_mid_run_registration_stay_bit_identical() {
    use alps::pruning::register_with_coordinator;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    let target = SparsityTarget::Unstructured(0.6);
    let spec = MethodSpec::Wanda;
    let spawn_worker = || {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let worker = Arc::new(Worker::new(WorkerConfig::default()));
        let w = worker.clone();
        let serve = std::thread::spawn(move || {
            let _ = w.serve(listener);
        });
        (addr, worker, serve)
    };

    let (addr_a, worker_a, serve_a) = spawn_worker();
    let (addr_b, worker_b, serve_b) = spawn_worker();
    let mut engine = ShardedEngine::with_config(
        spec.clone(),
        vec![addr_a.clone(), addr_b.clone()],
        quick_cfg(),
    )
    .unwrap();
    let board = Arc::new(StatusBoard::new());
    engine.set_status_board(board.clone());
    let reg = engine.listen_for_registrations("127.0.0.1:0").unwrap();

    // block 1 runs on the seed fleet
    let jobs1 = random_problems(6, 81);
    let r1 = engine.solve_block(&jobs1, target).unwrap();
    assert!((worker_a.layers_solved() + worker_b.layers_solved()) >= jobs1.len());

    // replacement C joins mid-run through the REGISTER handshake, then
    // both seed workers die: their parked connections go dead and every
    // redial is refused, so the pool must write them off and hand the
    // whole next block to C
    let (addr_c, worker_c, _serve_c) = spawn_worker();
    let stop = AtomicBool::new(false);
    register_with_coordinator(&reg, &addr_c, &stop).unwrap();
    worker_a.request_shutdown();
    worker_b.request_shutdown();
    // join the serve threads: the kill must be complete (listeners
    // closed, parked connections dropped) before the next block, so no
    // straggler solve can land on a dying seed worker
    serve_a.join().unwrap();
    serve_b.join().unwrap();

    let jobs2 = random_problems(6, 82);
    let r2 = engine.solve_block(&jobs2, target).unwrap();

    let n1 = NativeEngine::new(spec.clone()).solve_block(&jobs1, target).unwrap();
    let n2 = NativeEngine::new(spec).solve_block(&jobs2, target).unwrap();
    for (i, (r, l)) in r1.iter().zip(&n1).enumerate() {
        assert_eq!(r.w, l.w, "pre-churn layer {i} not bit-identical");
    }
    for (i, (r, l)) in r2.iter().zip(&n2).enumerate() {
        assert_eq!(r.w, l.w, "post-churn layer {i} not bit-identical");
        assert_eq!(r.worker.as_deref(), Some(addr_c.as_str()), "layer {i}");
    }
    assert_eq!(worker_c.layers_solved(), jobs2.len());

    // the board saw the whole membership history: three joins (two seed,
    // one registered), two permanent departures, one survivor
    let st = board.snapshot();
    let joins: Vec<&str> = st
        .fleet_events
        .iter()
        .filter(|(_, ev, _)| ev == "join")
        .map(|(_, _, w)| w.as_str())
        .collect();
    let leaves: Vec<&str> = st
        .fleet_events
        .iter()
        .filter(|(_, ev, _)| ev == "leave")
        .map(|(_, _, w)| w.as_str())
        .collect();
    assert!(joins.contains(&addr_a.as_str()), "{joins:?}");
    assert!(joins.contains(&addr_b.as_str()), "{joins:?}");
    assert!(joins.contains(&addr_c.as_str()), "{joins:?}");
    assert!(leaves.contains(&addr_a.as_str()), "{leaves:?}");
    assert!(leaves.contains(&addr_b.as_str()), "{leaves:?}");
    assert_eq!(st.fleet, 1, "only the registered replacement remains");
    assert!(
        st.fleet_series.iter().any(|&(_, n)| n == 3),
        "series never saw the 3-member fleet: {:?}",
        st.fleet_series
    );

    engine.close();
    worker_c.request_shutdown();
}

/// The status endpoint serves a live snapshot of a sharded run with
/// per-worker layer attribution.
#[test]
fn status_endpoint_reports_sharded_progress() {
    let calib = calib_seqs(3, 8, 24, 31);
    let target = SparsityTarget::Unstructured(0.5);
    let spec = MethodSpec::Magnitude;

    let worker_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let worker_addr = worker_listener.local_addr().unwrap().to_string();
    let worker = std::sync::Arc::new(Worker::new(WorkerConfig::default()));
    let w2 = worker.clone();
    std::thread::spawn(move || {
        let _ = w2.serve(worker_listener);
    });

    let status_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let status_addr = status_listener.local_addr().unwrap();
    let board = StatusBoard::new();
    let status = StatusServer::new();
    std::thread::scope(|s| {
        let srv = s.spawn(|| status.serve(status_listener, &board));
        let engine = ShardedEngine::with_config(
            spec.clone(),
            vec![worker_addr.clone()],
            quick_cfg(),
        )
        .unwrap();
        let mut model = Model::random(tiny_cfg("shard-status"), 5).unwrap();
        PruneSession::builder()
            .calib(calib)
            .target(target)
            .engine(Box::new(engine))
            .observer(|ev| board.observe(ev))
            .run(&mut model)
            .unwrap();

        // query the endpoint after the run: the snapshot must attribute
        // every layer to the worker and mark the run finished
        let mut st = TcpStream::connect(status_addr).unwrap();
        st.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        writeln!(st, "status").unwrap();
        let mut resp = String::new();
        std::io::Read::read_to_string(&mut st, &mut resp).unwrap();
        // shut the server down before asserting: a failed assert must
        // fail the test, not hang the scope join on a live accept loop
        status.request_shutdown();
        srv.join().unwrap().unwrap();
        assert!(resp.contains("\"finished\":true"), "{resp}");
        assert!(resp.contains("\"layers_solved\":12"), "{resp}");
        assert!(
            resp.contains(&format!("\"{worker_addr}\":12")),
            "per-worker attribution missing: {resp}"
        );
    });
    worker.request_shutdown();
}
