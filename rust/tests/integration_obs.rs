//! Observability integration: every TCP endpoint must answer
//! `GET /metrics` with valid Prometheus text **while real work is in
//! flight**, and the scrape must come back promptly — it reads the
//! process-global [`alps::obs`] registry without taking the batcher or
//! session locks, so a saturated server stays observable.
//!
//! Two scenarios:
//!
//! * the serve front-end is scraped while a batch of generations is
//!   decoding (`alps_serve_*` + `alps_net_*` families);
//! * a sharded prune run is paused between layer solves (the observer
//!   blocks on a rendezvous channel) while the `--status-addr` endpoint
//!   and the worker port are both scraped mid-run (`alps_prune_*`,
//!   `alps_coord_*`, `alps_net_*` families), then the run resumes and
//!   must still finish cleanly.

use alps::config::{AlpsConfig, ModelConfig, SparsityTarget};
use alps::coordinator::ShardedEngine;
use alps::model::Model;
use alps::pruning::{
    MethodSpec, ProgressEvent, PruneSession, StatusBoard, StatusServer, Worker, WorkerConfig,
};
use alps::serve::{Engine as ServeEngine, SamplingParams, TcpConfig};
use alps::util::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn tiny_cfg(name: &str) -> ModelConfig {
    ModelConfig {
        name: name.into(),
        d_model: 16,
        d_ff: 32,
        n_layers: 2,
        n_heads: 4,
        vocab: 24,
        seq_len: 12,
    }
}

fn calib_seqs(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u16>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.below(vocab) as u16).collect())
        .collect()
}

/// One timed `GET /metrics` scrape. Returns the raw HTTP response and
/// the wall time it took — callers assert the scrape never waits on a
/// work lock (a stuck scrape would eat the whole read timeout instead).
fn scrape_metrics(addr: SocketAddr) -> (String, f64) {
    let start = Instant::now();
    let mut st = TcpStream::connect(addr).expect("connect for scrape");
    st.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(st, "GET /metrics HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    let _ = st.shutdown(std::net::Shutdown::Write);
    let mut resp = String::new();
    st.read_to_string(&mut resp).expect("read scrape response");
    (resp, start.elapsed().as_secs_f64())
}

fn assert_prometheus_page(resp: &str, families: &[&str], ctx: &str) {
    assert!(resp.starts_with("HTTP/1.1 200 OK"), "{ctx}: not a 200: {resp}");
    assert!(resp.contains("text/plain; version=0.0.4"), "{ctx}: wrong content type: {resp}");
    for fam in families {
        assert!(resp.contains(fam), "{ctx}: missing family {fam}:\n{resp}");
    }
}

/// Serve front-end: queue a batch, ask for results (`run` blocks the
/// client connection on generation), and scrape `/metrics` from a second
/// connection while that batch decodes. The scrape must answer without
/// touching the batcher lock, carry the serve + net families, and the
/// protocol connection must still deliver every result afterwards.
#[test]
fn serve_frontend_metrics_scrape_under_load() {
    let model = Model::random(tiny_cfg("obs-serve"), 3).unwrap();
    let engine = ServeEngine::dense(&model).unwrap();
    let params = SamplingParams { max_new_tokens: 24, ..Default::default() };
    let cfg = TcpConfig { max_batch: 4, max_conns: 8, max_line_bytes: 4096 };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    std::thread::scope(|s| {
        let srv = s.spawn(|| alps::serve::tcp::serve(listener, &engine, &params, &cfg));

        let mut client = TcpStream::connect(addr).unwrap();
        client.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut rng = Rng::new(5);
        let n_req = 6usize;
        for _ in 0..n_req {
            let prompt: Vec<String> =
                (0..6).map(|_| rng.below(model.cfg.vocab).to_string()).collect();
            writeln!(client, "{}", prompt.join(" ")).unwrap();
            let mut ack = String::new();
            reader.read_line(&mut ack).unwrap();
            assert!(ack.starts_with("queued "), "ack: {ack}");
        }
        // `run` makes the server decode the whole batch before replying —
        // the scrape below races that decode, which is exactly the point
        writeln!(client, "run").unwrap();

        let (resp, secs) = scrape_metrics(addr);
        assert!(secs < 10.0, "scrape under load took {secs}s — did it block?");
        assert_prometheus_page(
            &resp,
            &[
                "# TYPE alps_serve_tokens_total counter",
                "alps_serve_steps_total",
                "alps_serve_step_seconds_bucket",
                "alps_net_connections_total",
            ],
            "serve front-end",
        );

        for _ in 0..n_req {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("ok "), "result line: {line}");
        }
        drop(reader);
        drop(client);

        // a scrape after the load shows the work that just happened
        let (resp, _) = scrape_metrics(addr);
        assert_prometheus_page(&resp, &["alps_serve_requests_total"], "serve post-load");

        let mut shut = TcpStream::connect(addr).unwrap();
        writeln!(shut, "shutdown").unwrap();
        let report = srv.join().expect("serve thread panicked").unwrap();
        assert!(report.contains("tokens/s (decode)"), "report: {report}");
    });
}

/// Sharded prune run with a status endpoint and a loopback worker: the
/// observer pauses the session right after the first layer solve so the
/// "mid-run" scrapes are deterministic, then the run resumes. Both the
/// status port and the worker port must answer `/metrics` while the
/// session is live, and the status JSON must carry the elapsed-time
/// bookkeeping (`elapsed_secs`, `block_secs`).
#[test]
fn status_and_worker_ports_scrape_during_live_prune_run() {
    let calib = calib_seqs(4, 8, 24, 11);
    let target = SparsityTarget::Unstructured(0.6);
    let spec = MethodSpec::Alps(AlpsConfig { max_iters: 40, ..Default::default() });
    let mut model = Model::random(tiny_cfg("obs-prune"), 77).unwrap();

    let wl = TcpListener::bind("127.0.0.1:0").unwrap();
    let worker_addr = wl.local_addr().unwrap();
    let worker = Arc::new(Worker::new(WorkerConfig::default()));
    let w = worker.clone();
    std::thread::spawn(move || {
        let _ = w.serve(wl);
    });

    let board = StatusBoard::new();
    let status = StatusServer::new();
    let sl = TcpListener::bind("127.0.0.1:0").unwrap();
    let status_addr = sl.local_addr().unwrap();

    let (solved_tx, solved_rx) = mpsc::channel::<()>();
    let (resume_tx, resume_rx) = mpsc::channel::<()>();

    std::thread::scope(|s| {
        let srv = s.spawn(|| status.serve(sl, &board));
        // the channel endpoints are !Sync, so the runner captures its
        // half by move; the board is shared by reference with the server
        let board_ref = &board;
        let spec2 = spec.clone();
        let runner = s.spawn(move || {
            let addrs = vec![worker_addr.to_string()];
            let engine = ShardedEngine::with_config(spec2, addrs, Default::default()).unwrap();
            let mut paused = false;
            PruneSession::builder()
                .calib(calib)
                .target(target)
                .engine(Box::new(engine))
                .observer(|ev| {
                    board_ref.observe(ev);
                    if !paused && matches!(ev, ProgressEvent::LayerSolved { .. }) {
                        paused = true;
                        let _ = solved_tx.send(());
                        // hold the session here while the main thread
                        // scrapes: the run is provably mid-flight
                        let _ = resume_rx.recv_timeout(Duration::from_secs(60));
                    }
                })
                .run(&mut model)
        });

        solved_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("no layer solved within 60s");

        // run is paused mid-block: scrape the status endpoint...
        let (resp, secs) = scrape_metrics(status_addr);
        assert!(secs < 10.0, "status scrape took {secs}s mid-run");
        assert_prometheus_page(
            &resp,
            &[
                "# TYPE alps_prune_layers_total counter",
                "alps_prune_block",
                "alps_net_connections_total",
                "alps_coord_rpc_seconds",
                "alps_coord_wire_tx_bytes_total",
            ],
            "status endpoint mid-run",
        );
        // ...and the worker port, which shares the obs registry and
        // sniffs HTTP apart from the frame protocol on the same socket
        let (resp, secs) = scrape_metrics(worker_addr);
        assert!(secs < 10.0, "worker scrape took {secs}s mid-run");
        assert_prometheus_page(
            &resp,
            &["alps_net_frames_total", "alps_net_frame_bytes_total"],
            "worker port mid-run",
        );

        resume_tx.send(()).unwrap();
        let report = runner.join().expect("run thread panicked").unwrap();
        assert!(!report.layers.is_empty());
        assert_eq!(report.method, format!("sharded({})", spec.label()));

        // post-run: the status JSON carries the timing bookkeeping
        let mut st = TcpStream::connect(status_addr).unwrap();
        st.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(st, "GET /status HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let _ = st.shutdown(std::net::Shutdown::Write);
        let mut json = String::new();
        st.read_to_string(&mut json).unwrap();
        assert!(json.contains("\"elapsed_secs\":"), "{json}");
        assert!(json.contains("\"block_secs\":{"), "{json}");
        assert!(json.contains("\"finished\":true"), "{json}");

        status.request_shutdown();
        srv.join().expect("status server panicked").unwrap();
    });
}
