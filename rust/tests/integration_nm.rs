//! End-to-end exactness of the packed N:M serving path: the `NmModel`
//! backend must be **bit-identical** to the CSR `SparseModel` backend at
//! every level — per-step logits, batched prefill logits, and full
//! `Engine::generate` token streams — on a 2:4-pruned alps-tiny model.

use alps::config::ModelConfig;
use alps::model::{Decoder, Model, SparseModel};
use alps::pruning::projection::nm_project;
use alps::serve::{Engine, SamplingParams};
use alps::sparse::{NmModel, NmPacked};

/// alps-tiny with every prunable layer 2:4-projected (magnitude).
fn nm_pruned_tiny(seed: u64) -> Model {
    let mut model = Model::random(ModelConfig::preset("alps-tiny").unwrap(), seed).unwrap();
    for name in model.prunable_names() {
        let w = model.weights.matrix(&name).unwrap();
        model.weights.set_matrix(&name, &nm_project(&w, 2, 4)).unwrap();
    }
    model
}

#[test]
fn stepwise_logits_bit_identical_nm_vs_csr() {
    let model = nm_pruned_tiny(71);
    let nm = Decoder::new(&model, NmModel::from_model(&model, 2, 4).unwrap()).unwrap();
    let csr = Decoder::new(&model, SparseModel::from_model(&model).unwrap()).unwrap();
    let mut c_nm = nm.new_cache();
    let mut c_csr = csr.new_cache();
    for &tok in &[1u16, 5, 9, 2, 2, 17, 300, 7] {
        let a = nm.step(&mut c_nm, tok).unwrap();
        let b = csr.step(&mut c_csr, tok).unwrap();
        assert_eq!(a, b, "step logits diverged at token {tok}");
    }
}

#[test]
fn prefill_batch_bit_identical_nm_vs_csr() {
    let model = nm_pruned_tiny(72);
    let nm = Decoder::new(&model, NmModel::from_model(&model, 2, 4).unwrap()).unwrap();
    let csr = Decoder::new(&model, SparseModel::from_model(&model).unwrap()).unwrap();
    let prompt: Vec<u16> = (0..24).map(|i| (i * 13 % 500) as u16).collect();
    let mut c_nm = nm.new_cache();
    let mut c_csr = csr.new_cache();
    let a = nm.prefill_batch(&mut c_nm, &prompt).unwrap();
    let b = csr.prefill_batch(&mut c_csr, &prompt).unwrap();
    assert_eq!(a, b, "batched prefill logits diverged");
    assert_eq!(c_nm.len(), c_csr.len());
}

#[test]
fn generate_tokens_identical_across_all_three_backends() {
    let model = nm_pruned_tiny(73);
    let e_nm = Engine::nm(&model, 2, 4).unwrap();
    let e_csr = Engine::sparse(&model).unwrap();
    let e_dense = Engine::dense(&model).unwrap();
    assert!(
        e_nm.label().contains("12/12 packed"),
        "fully 2:4 model must pack every layer, got '{}'",
        e_nm.label()
    );
    let params = SamplingParams { max_new_tokens: 12, ..Default::default() };
    for prompt in [vec![1u16, 2, 3], vec![9, 8, 7, 6, 5], vec![400, 0, 255]] {
        let g_nm = e_nm.generate(&prompt, &params, 0).unwrap();
        let g_csr = e_csr.generate(&prompt, &params, 0).unwrap();
        let g_dense = e_dense.generate(&prompt, &params, 0).unwrap();
        assert_eq!(g_nm.tokens, g_csr.tokens, "nm vs csr tokens for {prompt:?}");
        assert_eq!(g_nm.tokens, g_dense.tokens, "nm vs dense tokens for {prompt:?}");
    }
}

#[test]
fn mixed_checkpoint_serves_with_per_layer_fallback() {
    // prune all but one layer: that layer cannot pack, so NmModel keeps a
    // CSR fallback for it — and the engine still matches the CSR backend.
    let mut model = Model::random(ModelConfig::preset("alps-tiny").unwrap(), 74).unwrap();
    let names = model.prunable_names();
    for name in names.iter().skip(1) {
        let w = model.weights.matrix(name).unwrap();
        model.weights.set_matrix(name, &nm_project(&w, 2, 4)).unwrap();
    }
    let nm = NmModel::from_model(&model, 2, 4).unwrap();
    assert_eq!(nm.layer_count(), names.len());
    assert_eq!(nm.packed_layers(), names.len() - 1, "dense layer must fall back to CSR");

    let e_nm = Engine::nm(&model, 2, 4).unwrap();
    let e_csr = Engine::sparse(&model).unwrap();
    let params = SamplingParams { max_new_tokens: 8, ..Default::default() };
    let g_nm = e_nm.generate(&[3, 1, 4, 1, 5], &params, 0).unwrap();
    let g_csr = e_csr.generate(&[3, 1, 4, 1, 5], &params, 0).unwrap();
    assert_eq!(g_nm.tokens, g_csr.tokens);
}

#[test]
fn packed_kernels_match_csr_on_pruned_layer_weights() {
    // kernel-level spot check on real pruned layer weights (not synthetic
    // patterns): row_matvec and left_matmul agree bitwise with Csr.
    use alps::linalg::{Csr, Matrix};
    use alps::util::Rng;
    let model = nm_pruned_tiny(75);
    let name = &model.prunable_names()[0];
    let w = model.weights.matrix(name).unwrap();
    let packed = NmPacked::from_dense(&w, 2, 4).unwrap();
    let csr = Csr::from_dense(&w);
    let mut rng = Rng::new(7);
    let x: Vec<f32> = rng.gaussian_vec(w.rows);
    assert_eq!(packed.row_matvec(&x), csr.row_matvec(&x));
    let xm = Matrix::randn(3, w.rows, &mut rng);
    assert_eq!(packed.left_matmul(&xm), csr.left_matmul(&xm));
}
