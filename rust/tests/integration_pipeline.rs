//! End-to-end pipeline integration on the real trained artifacts: prune a
//! trained model through the coordinator, evaluate perplexity, verify the
//! paper's ordering. Skipped when artifacts have not been built.

use alps::config::SparsityTarget;
use alps::data::{sample_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::Model;
use alps::pruning::{MethodSpec, PruneSession};
use std::path::Path;

/// Prune through the session API with default method hyperparameters.
fn prune(model: &mut Model, calib: Vec<Vec<u16>>, target: SparsityTarget, method: &str) {
    PruneSession::builder()
        .calib(calib)
        .target(target)
        .method(MethodSpec::parse(method).unwrap())
        .run(model)
        .unwrap();
}

fn have_artifacts() -> bool {
    let ok = Path::new("artifacts/model_alps-tiny.bin").exists()
        && Path::new("artifacts/corpus.bin").exists();
    if !ok {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    }
    ok
}

fn setup() -> (Model, Corpus, Vec<Vec<u16>>) {
    let dir = Path::new("artifacts");
    let model = Model::load(dir, "alps-tiny").unwrap();
    let corpus = Corpus::load(&dir.join("corpus.bin")).unwrap();
    let calib = sample_windows(corpus.split("train").unwrap(), 8, model.cfg.seq_len, 1);
    (model, corpus, calib)
}

#[test]
fn trained_model_has_low_perplexity() {
    if !have_artifacts() {
        return;
    }
    let (model, corpus, _) = setup();
    let ids = &corpus.split("wikitext2-like").unwrap()[..128 * 8];
    let ppl = perplexity(&model, ids).unwrap();
    assert!(ppl < 3.0, "dense trained ppl should be low, got {ppl}");
}

#[test]
fn e2e_alps_beats_mp_on_perplexity() {
    if !have_artifacts() {
        return;
    }
    let (model, corpus, calib) = setup();
    let eval_ids = &corpus.split("wikitext2-like").unwrap()[..128 * 6];
    let target = SparsityTarget::Unstructured(0.7);

    let mut m_alps = Model::load(Path::new("artifacts"), "alps-tiny").unwrap();
    let mut m_mp = Model::load(Path::new("artifacts"), "alps-tiny").unwrap();
    prune(&mut m_alps, calib.clone(), target, "alps");
    prune(&mut m_mp, calib, target, "mp");

    let ppl_dense = perplexity(&model, eval_ids).unwrap();
    let ppl_alps = perplexity(&m_alps, eval_ids).unwrap();
    let ppl_mp = perplexity(&m_mp, eval_ids).unwrap();
    assert!(ppl_dense <= ppl_alps, "pruning cannot improve ppl");
    assert!(
        ppl_alps < ppl_mp,
        "alps ppl {ppl_alps} must beat mp ppl {ppl_mp}"
    );
}

#[test]
fn e2e_sparsity_written_back() {
    if !have_artifacts() {
        return;
    }
    let (mut model, _, calib) = setup();
    let target = SparsityTarget::Unstructured(0.6);
    prune(&mut model, calib, target, "wanda");
    let names = model.prunable_names();
    let s = model.weights.sparsity_of(&names);
    assert!((s - 0.6).abs() < 0.03, "sparsity {s}");
    // non-prunable tensors untouched
    let dense = Model::load(Path::new("artifacts"), "alps-tiny").unwrap();
    assert_eq!(
        model.weights.get("tok_emb").unwrap().data,
        dense.weights.get("tok_emb").unwrap().data
    );
}

#[test]
fn e2e_nm_pipeline() {
    if !have_artifacts() {
        return;
    }
    let (mut model, corpus, calib) = setup();
    let target = SparsityTarget::NM { n: 2, m: 4 };
    prune(&mut model, calib, target, "alps");
    for name in model.prunable_names() {
        let w = model.weights.matrix(&name).unwrap();
        assert!(alps::pruning::check_target(&w, target), "{name}");
    }
    let eval_ids = &corpus.split("ptb-like").unwrap()[..128 * 4];
    let ppl = perplexity(&model, eval_ids).unwrap();
    assert!(ppl.is_finite() && ppl < 100.0, "2:4 ppl {ppl}");
}

#[test]
fn e2e_zero_shot_degrades_gracefully() {
    if !have_artifacts() {
        return;
    }
    let (model, corpus, calib) = setup();
    let ids = corpus.split("wikitext2-like").unwrap();
    let task = tasks::arc_easy_like(ids, 30, 32, 4, 0);
    let acc_dense = zero_shot_accuracy(&model, &task).unwrap();

    let mut m90 = Model::load(Path::new("artifacts"), "alps-tiny").unwrap();
    prune(&mut m90, calib, SparsityTarget::Unstructured(0.9), "mp");
    let acc_90 = zero_shot_accuracy(&m90, &task).unwrap();
    assert!(
        acc_dense >= acc_90,
        "90% MP pruning should not beat dense: {acc_dense} vs {acc_90}"
    );
}

#[test]
fn e2e_structured_pruning_removes_rows() {
    if !have_artifacts() {
        return;
    }
    let (model, _, calib) = setup();
    let p = alps::coordinator::scheduler::single_layer_problem(&model, &calib, 0, "mlp.w2")
        .unwrap();
    let w = alps::pruning::structured::StructuredAlps::default()
        .prune_rows(&p, 0.5)
        .unwrap();
    let rows = alps::pruning::structured::nonzero_rows(&w);
    assert!(rows <= p.n_in() / 2, "rows {rows}");
    // structured support must still beat zeroing the same rows naively
    let naive = alps::pruning::structured::structured_magnitude(&p, p.n_in() / 2);
    assert!(p.rel_error(&w) < p.rel_error(&naive) * 1.5);
}

#[test]
fn e2e_prune_then_quantize_small_ppl_cost() {
    if !have_artifacts() {
        return;
    }
    let (mut model, corpus, calib) = setup();
    prune(&mut model, calib.clone(), SparsityTarget::Unstructured(0.5), "alps");
    let ids = &corpus.split("wikitext2-like").unwrap()[..128 * 4];
    let ppl_pruned = perplexity(&model, ids).unwrap();
    for name in model.prunable_names() {
        let w = model.weights.matrix(&name).unwrap();
        let q = alps::pruning::quantize::QuantizedWeights::quantize(&w);
        model.weights.set_matrix(&name, &q.dequantize()).unwrap();
    }
    let ppl_quant = perplexity(&model, ids).unwrap();
    assert!(
        ppl_quant < ppl_pruned * 1.10,
        "int8 cost too high: {ppl_quant} vs {ppl_pruned}"
    );
}

#[test]
fn e2e_sparse_inference_matches_dense_ppl() {
    if !have_artifacts() {
        return;
    }
    let (mut model, corpus, calib) = setup();
    prune(&mut model, calib, SparsityTarget::Unstructured(0.7), "wanda");
    let sm = alps::model::sparse_infer::SparseModel::from_model(&model).unwrap();
    let ids = &corpus.split("ptb-like").unwrap()[..128 * 2];
    for w in ids.chunks_exact(128) {
        let a = model.nll(w).unwrap();
        let b = sm.nll(w).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }
    assert!((sm.density() - 0.3).abs() < 0.05);
}

#[test]
fn failure_injection_corrupt_weights_rejected() {
    if !have_artifacts() {
        return;
    }
    // truncated weights file must error, not panic or mis-load
    let src = std::fs::read("artifacts/model_alps-tiny.bin").unwrap();
    let dir = std::env::temp_dir().join("alps_fail_inject");
    std::fs::create_dir_all(&dir).unwrap();
    let trunc = dir.join("trunc.bin");
    std::fs::write(&trunc, &src[..src.len() / 2]).unwrap();
    assert!(alps::model::Weights::load(&trunc).is_err());
    // corrupted magic
    let mut bad = src.clone();
    bad[0] ^= 0xFF;
    let badp = dir.join("bad.bin");
    std::fs::write(&badp, &bad).unwrap();
    assert!(alps::model::Weights::load(&badp).is_err());
}

#[test]
fn failure_injection_corrupt_hlo_rejected() {
    if !have_artifacts() {
        return;
    }
    // a syntactically-broken HLO artifact must fail at compile, not crash
    let dir = std::env::temp_dir().join("alps_fail_hlo");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy("artifacts/manifest.json", dir.join("manifest.json")).unwrap();
    std::fs::write(dir.join("admm_iter_128x128.hlo.txt"), "HloModule garbage ???").unwrap();
    let rt = alps::runtime::Runtime::new(&dir).unwrap();
    use alps::runtime::client::Value;
    let z = alps::linalg::Matrix::zeros(128, 128);
    let inputs = vec![
        Value::matrix(&z),
        Value::vector(&[0.0; 128]),
        Value::matrix(&z),
        Value::matrix(&z),
        Value::matrix(&z),
        Value::scalar(1.0),
        Value::I32(10),
    ];
    assert!(rt.run("admm_iter_128x128", &inputs).is_err());
}

#[test]
fn e2e_save_load_pruned_checkpoint() {
    if !have_artifacts() {
        return;
    }
    let (mut model, corpus, calib) = setup();
    prune(&mut model, calib, SparsityTarget::Unstructured(0.5), "sparsegpt");
    let path = std::env::temp_dir().join("alps_e2e_ckpt.bin");
    model.weights.save(&path).unwrap();
    let reloaded = alps::model::Weights::load(&path).unwrap();
    let mut m2 = Model::load(Path::new("artifacts"), "alps-tiny").unwrap();
    m2.weights = reloaded;
    let ids = &corpus.split("c4-like").unwrap()[..128 * 3];
    let p1 = perplexity(&model, ids).unwrap();
    let p2 = perplexity(&m2, ids).unwrap();
    assert!((p1 - p2).abs() < 1e-9, "{p1} vs {p2}");
}

#[test]
fn e2e_sharded_prune_matches_native_end_to_end() {
    // no artifacts needed: the whole pipeline (calibration capture ->
    // gram -> sharded solve over a loopback worker -> write-back) on a
    // synthetic model must be bit-identical to the in-process engine
    use alps::config::ModelConfig;
    use alps::coordinator::{ShardedConfig, ShardedEngine};
    use alps::pruning::worker::{Worker, WorkerConfig};
    use std::net::TcpListener;
    use std::sync::Arc;
    use std::time::Duration;

    let cfg = ModelConfig {
        name: "sharded-e2e".into(),
        d_model: 16,
        d_ff: 32,
        n_layers: 2,
        n_heads: 4,
        vocab: 24,
        seq_len: 12,
    };
    // one 8-token sequence keeps the activation rows (8) below every
    // layer's n_in (16/32), so the ship-activations engine below really
    // ships X instead of falling back to the smaller-gram encoding
    let mut rng = alps::util::Rng::new(0xD157);
    let calib: Vec<Vec<u16>> = (0..1)
        .map(|_| (0..8).map(|_| rng.below(24) as u16).collect())
        .collect();
    let target = SparsityTarget::Unstructured(0.6);
    let spec = MethodSpec::parse("sparsegpt").unwrap();

    let mut m_native = Model::random(cfg.clone(), 1234).unwrap();
    prune(&mut m_native, calib.clone(), target, "sparsegpt");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let worker = Arc::new(Worker::new(WorkerConfig::default()));
    let w = worker.clone();
    std::thread::spawn(move || {
        let _ = w.serve(listener);
    });
    // ship activations end-to-end: the worker builds the grams itself,
    // which must not change a single bit of the result
    let engine = ShardedEngine::with_config(
        spec,
        vec![addr],
        ShardedConfig {
            retry_backoff: Duration::from_millis(10),
            ship_activations: true,
            ..Default::default()
        },
    )
    .unwrap();
    let mut m_sharded = Model::random(cfg, 1234).unwrap();
    PruneSession::builder()
        .calib(calib)
        .target(target)
        .engine(Box::new(engine))
        .run(&mut m_sharded)
        .unwrap();
    worker.request_shutdown();

    for (name, t_native) in &m_native.weights.tensors {
        let t_sharded = m_sharded.weights.tensors.get(name).unwrap();
        assert_eq!(
            t_native.data, t_sharded.data,
            "tensor '{name}' differs between native and sharded e2e runs"
        );
    }
}
