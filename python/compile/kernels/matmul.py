"""Tiled Pallas matmul — the ADMM/PCG hot-spot kernel.

TPU mapping of the paper's cuBLAS GEMMs (Sec. 3.2/3.3): the HBM<->VMEM
schedule the paper expressed with CUDA threadblocks is expressed here with a
3-D grid and BlockSpecs. Block shapes target the MXU systolic array:

  * bm = bn = 128 matches the 128x128 MXU tile;
  * the K axis is the innermost grid dimension so each (i, j) output tile
    stays resident in VMEM while partial products accumulate in f32;
  * VMEM footprint per step = bm*bk + bk*bn + bm*bn f32 words
    (3 * 128 * 128 * 4 B = 192 KiB << 16 MiB VMEM), leaving room for
    double-buffering the A/B tiles.

``interpret=True`` everywhere: on this testbed the kernel is executed by the
Pallas interpreter (and lowers to plain HLO), which validates structure and
numerics; real-TPU performance is estimated in DESIGN.md §Hardware-Adaptation.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i, j] += a[i, k] @ b[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation regardless of input dtype (MXU-style).
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (keeps grid exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(a, b, bm: int = 128, bk: int = 128, bn: int = 128):
    """C = A @ B with a tiled Pallas kernel (f32 accumulation).

    Shapes: a [M, K], b [K, N] -> [M, N]. Block sizes are clamped to exact
    divisors of each dimension so the grid covers the operands exactly
    (production TPU kernels would pad instead; exact division keeps the
    interpret-mode HLO small).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims mismatch: {a.shape} @ {b.shape}"
    bm = _pick_block(m, bm)
    bk = _pick_block(k, bk)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_footprint_bytes(bm: int, bk: int, bn: int, itemsize: int = 4) -> int:
    """VMEM bytes resident per grid step (one A tile, one B tile, one C tile).

    Used by DESIGN.md §Perf to justify block choices and by the pytest suite
    as a budget guard (< 16 MiB with 2x double-buffering headroom).
    """
    return (bm * bk + bk * bn + bm * bn) * itemsize


def mxu_utilization_estimate(bm: int, bk: int, bn: int) -> float:
    """Crude MXU utilization proxy: useful MACs per operand word moved.

    A 128x128x128 tile gives 2*128^3 flops over 3*128^2 words -> ratio ~85:1,
    i.e. compute-bound on the MXU; ratios below ~8 indicate a memory-bound
    schedule. Recorded (not measured) because interpret mode has no MXU.
    """
    flops = 2.0 * bm * bk * bn
    words = float(bm * bk + bk * bn + bm * bn)
    return flops / words
