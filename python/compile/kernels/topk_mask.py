"""Pallas kernel: thresholded magnitude masking (ADMM D-update tail).

The global top-k projection P_k splits into (1) finding the k-th largest
magnitude (a global sort — done once in the surrounding jax graph) and
(2) the embarrassingly-parallel mask application ``x * (|x| >= t)`` which is
this kernel. Blocked elementwise over VMEM tiles; the threshold rides along
as a (1, 1) operand in SMEM-style replication.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mask_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0, 0]
    o_ref[...] = x * (jnp.abs(x) >= t).astype(x.dtype)


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def topk_mask(x, thresh, bm: int = 256, bn: int = 256):
    """x * (|x| >= thresh) for x [M, N], thresh scalar (traced)."""
    m, n = x.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    t = jnp.asarray(thresh, dtype=x.dtype).reshape(1, 1)
    return pl.pallas_call(
        _mask_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, t)
