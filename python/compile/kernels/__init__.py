"""Layer-1 Pallas kernels for ALPS.

All kernels are authored for TPU (VMEM tiling, MXU-shaped matmuls) but are
lowered with ``interpret=True`` so the resulting HLO runs on the CPU PJRT
client used by the rust runtime. Correctness is pinned against the pure-jnp
oracles in :mod:`compile.kernels.ref` by the pytest/hypothesis suite.
"""
from . import matmul, nm_project, pcg_step, topk_mask, ref  # noqa: F401
