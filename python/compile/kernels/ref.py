"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: slow, obvious implementations with
no tiling or fusion. The pytest suite sweeps shapes/dtypes with hypothesis
and asserts ``assert_allclose(kernel(...), ref(...))``.
"""
import jax.numpy as jnp


def matmul(a, b):
    """Plain dense matmul in f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def nm_project(z, n_keep: int):
    """N:M projection oracle.

    ``z`` has shape [G, M] (groups of M consecutive weights); keep the
    ``n_keep`` largest-magnitude entries of each row, zero the rest.
    Ties are broken toward the lower index (stable), matching the kernel.
    """
    absz = jnp.abs(z)
    idx = jnp.arange(z.shape[1])
    # rank_i = #{j : |z_j| > |z_i|  or (|z_j| == |z_i| and j < i)}  (stable)
    gt_ji = absz[:, :, None] < absz[:, None, :]  # [G, i, j] -> |z_j| > |z_i|
    eq_ji = (absz[:, :, None] == absz[:, None, :]) & (idx[None, None, :] < idx[None, :, None])
    rank = jnp.sum(gt_ji | eq_ji, axis=-1)
    mask = (rank < n_keep).astype(z.dtype)
    return z * mask


def topk_mask(x, thresh):
    """Zero entries whose magnitude is below ``thresh`` (scalar)."""
    return x * (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_project(x, k: int):
    """Exact global top-k magnitude projection (rank-based, tie-stable)."""
    flat = jnp.abs(x).reshape(-1)
    order = jnp.argsort(-flat, stable=True)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(flat.shape[0]))
    mask = (ranks < k).astype(x.dtype).reshape(x.shape)
    return x * mask


def pcg_elementwise(w, p, r, hp, mask, invdiag, alpha):
    """Fused PCG inner-step elementwise oracle.

    w   += alpha * p
    r   -= alpha * hp          (then projected onto the support mask)
    z    = invdiag * r
    Returns (w_new, r_new, z_new).
    """
    w_new = w + alpha * p
    r_new = (r - alpha * hp) * mask
    z_new = invdiag * r_new
    return w_new, r_new, z_new
