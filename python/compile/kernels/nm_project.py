"""Pallas kernel: N:M structured projection (Sec. 3.2, "Extension to N:M").

The ADMM D-update for N:M sparsity replaces the global top-k projection with
a per-group projection: within every group of M consecutive weights (along
the input dimension), keep the N largest-magnitude entries.

The kernel operates on a [G, M] view (G groups of M weights). M is tiny
(4 or 8), so the per-row selection is done with an O(M^2) rank comparison —
fully vectorized, no sort — which maps onto the TPU VPU as M broadcast
compares per element. Rows are blocked so each step works on a
[block_g, M] VMEM tile.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nm_kernel(z_ref, o_ref, *, n_keep: int):
    z = z_ref[...]
    absz = jnp.abs(z)
    m = z.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    # rank_i = #{j : |z_j| > |z_i| or (|z_j| == |z_i| and j < i)}
    gt = absz[:, :, None] < absz[:, None, :]
    eq = (absz[:, :, None] == absz[:, None, :]) & (idx[:, None, :] < idx[:, :, None])
    rank = jnp.sum((gt | eq).astype(jnp.int32), axis=-1)
    mask = (rank < n_keep).astype(z.dtype)
    o_ref[...] = z * mask


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("n_keep", "block_g"))
def nm_project(z, n_keep: int, block_g: int = 1024):
    """Project z [G, M] onto rows with at most ``n_keep`` non-zeros."""
    g, m = z.shape
    bg = _pick_block(g, block_g)
    return pl.pallas_call(
        functools.partial(_nm_kernel, n_keep=n_keep),
        grid=(g // bg,),
        in_specs=[pl.BlockSpec((bg, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bg, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, m), z.dtype),
        interpret=True,
    )(z)


def nm_project_matrix(w, n_keep: int, group: int):
    """Apply N:M projection to a weight matrix W [N_in, N_out].

    Groups are M *consecutive weights along the input dimension* of each
    output neuron (paper / NVIDIA 2:4 convention): column j of W is split
    into N_in/group groups. Implemented by a transpose-reshape round-trip
    around the [G, M] kernel.
    """
    n_in, n_out = w.shape
    assert n_in % group == 0, f"N_in={n_in} not divisible by group={group}"
    wt = w.T.reshape(n_out * (n_in // group), group)
    pt = nm_project(wt, n_keep)
    return pt.reshape(n_out, n_in).T
