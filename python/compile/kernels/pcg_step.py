"""Pallas kernel: fused PCG elementwise step (Algorithm 2, lines 6-9).

One PCG iteration is a matmul H @ P (the `matmul` kernel / XLA dot) plus a
chain of elementwise updates that the paper fuses on the GPU:

    W <- W + alpha * P
    R <- (R - alpha * HP) * mask        (line 8: project R onto support S)
    Z <- invdiag * R                    (line 9: Jacobi preconditioner)

Fusing them in one Pallas kernel means each of the five [N_in, N_out]
operands streams through VMEM exactly once per iteration instead of five
kernel launches with five HBM round-trips — the TPU analogue of the paper's
"vectorization in a single pass".
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pcg_kernel(w_ref, p_ref, r_ref, hp_ref, mask_ref, invd_ref, alpha_ref,
                w_out, r_out, z_out):
    alpha = alpha_ref[0, 0]
    w = w_ref[...]
    p = p_ref[...]
    r = r_ref[...]
    hp = hp_ref[...]
    mask = mask_ref[...]
    invd = invd_ref[...]  # [bm, 1] column of the Jacobi preconditioner
    w_out[...] = w + alpha * p
    r_new = (r - alpha * hp) * mask
    r_out[...] = r_new
    z_out[...] = invd * r_new


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def pcg_elementwise(w, p, r, hp, mask, invdiag, alpha, bm: int = 256, bn: int = 256):
    """Fused elementwise PCG update.

    Shapes: w/p/r/hp/mask [M, N]; invdiag [M, 1] (1/diag(H), Jacobi
    preconditioner); alpha scalar (traced). Returns (w_new, r_new, z_new).
    """
    m, n = w.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    a = jnp.asarray(alpha, dtype=w.dtype).reshape(1, 1)
    invd = jnp.asarray(invdiag, dtype=w.dtype).reshape(m, 1)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    col = pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
    scl = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    shp = jax.ShapeDtypeStruct((m, n), w.dtype)
    return pl.pallas_call(
        _pcg_kernel,
        grid=(m // bm, n // bn),
        in_specs=[tile, tile, tile, tile, tile, col, scl],
        out_specs=(tile, tile, tile),
        out_shape=(shp, shp, shp),
        interpret=True,
    )(w, p, r, hp, mask, invd, a)
