"""Layer-2 JAX compute graphs for ALPS (build-time only).

Everything here is lowered once by :mod:`compile.aot` to HLO text and
executed from the rust coordinator via PJRT. The graphs call the Layer-1
Pallas kernels (``use_pallas=True``) or equivalent jnp ops; both lower into
the same HLO artifact format and are cross-checked by the pytest suite.

Graphs
------
admm_iter        one iteration of Algorithm 1 (eq. 4) with runtime rho and
                 runtime sparsity-k (exact rank-based top-k projection)
admm_iter_nm     same with the N:M projection D-update
pcg_refine       T iterations of Algorithm 2 under a fori_loop
gram             XtX and XtX @ What in one pass
transformer      tiny decoder-only GPT: init / apply / per-position NLL
"""
import functools
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import matmul as kmatmul
from .kernels import nm_project as knm
from .kernels import pcg_step as kpcg
from .kernels import topk_mask as ktopk


# --------------------------------------------------------------------------
# dispatch helpers: pallas kernel vs plain jnp (both paths exported/tested)
# --------------------------------------------------------------------------

def _dot(a, b, use_pallas: bool):
    if use_pallas:
        return kmatmul.matmul(a, b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _apply_mask(x, thresh, use_pallas: bool):
    if use_pallas:
        return ktopk.topk_mask(x, thresh)
    return x * (jnp.abs(x) >= thresh).astype(x.dtype)


# --------------------------------------------------------------------------
# projections
# --------------------------------------------------------------------------

def topk_project_exact(z, k):
    """Exact top-k magnitude projection with a *runtime* k (i32 scalar).

    Rank-based: argsort magnitudes descending (stable), scatter ranks back,
    keep rank < k. Exactly k non-zeros for any tie pattern.
    """
    shape = z.shape
    flat = jnp.abs(z).reshape(-1)
    order = jnp.argsort(-flat, stable=True)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(flat.shape[0], dtype=order.dtype))
    mask = (ranks < k).astype(z.dtype).reshape(shape)
    return z * mask, mask


def topk_threshold(z, k):
    """k-th largest magnitude of z (runtime k) — used with the mask kernel."""
    flat = jnp.sort(jnp.abs(z).reshape(-1))[::-1]
    return lax.dynamic_slice(flat, (k - 1,), (1,))[0]


def nm_project_matrix(w, n_keep: int, group: int, use_pallas: bool):
    if use_pallas:
        return knm.nm_project_matrix(w, n_keep, group)
    n_in, n_out = w.shape
    wt = w.T.reshape(n_out * (n_in // group), group)
    absz = jnp.abs(wt)
    idx = jnp.arange(group)
    gt = absz[:, :, None] < absz[:, None, :]
    eq = (absz[:, :, None] == absz[:, None, :]) & (idx[None, None, :] < idx[None, :, None])
    rank = jnp.sum((gt | eq).astype(jnp.int32), axis=-1)
    pt = wt * (rank < n_keep).astype(wt.dtype)
    return pt.reshape(n_out, n_in).T


# --------------------------------------------------------------------------
# ADMM iteration (Algorithm 1, update rules (4))
# --------------------------------------------------------------------------

def admm_iter(q, m_eig, g, d, v, rho, k, *, use_pallas: bool = False):
    """One ADMM iteration with runtime rho (f32) and k (i32).

    Inputs
      q      [n, n]  eigenvectors of H = XtX           (computed in rust)
      m_eig  [n]     eigenvalues of H
      g      [n, m]  XtX @ What (precomputed, constant across iterations)
      d, v   [n, m]  current D and dual V
      rho    []      penalty parameter
      k      []      sparsity budget (number of non-zeros to keep)

    Returns (w, d_new, v_new, delta_support, nnz):
      w      the W-update  (H + rho I)^-1 (G - V + rho D)
             computed as Q diag(1/(m+rho)) Q^T (G - V + rho D)
      delta_support  #{ij : supp(D_new) != supp(D)}  (drives the rho scheme)
      nnz    #non-zeros of D_new (sanity: == k)
    """
    invd = (1.0 / (m_eig + rho)).astype(jnp.float32)
    b = g - v + rho * d
    qtb = _dot(q.T, b, use_pallas)
    w = _dot(q, invd[:, None] * qtb, use_pallas)
    z = w + v / rho
    d_new, mask_new = topk_project_exact(z, k)
    v_new = v + rho * (w - d_new)
    mask_old = (d != 0.0).astype(jnp.float32)
    delta = jnp.sum(jnp.abs(mask_new - mask_old))
    nnz = jnp.sum(mask_new)
    return w, d_new, v_new, delta[None], nnz[None]


def admm_iter_nm(q, m_eig, g, d, v, rho, *, n_keep: int, group: int,
                 use_pallas: bool = False):
    """ADMM iteration with the N:M projection D-update (static N, M)."""
    invd = (1.0 / (m_eig + rho)).astype(jnp.float32)
    b = g - v + rho * d
    qtb = _dot(q.T, b, use_pallas)
    w = _dot(q, invd[:, None] * qtb, use_pallas)
    z = w + v / rho
    d_new = nm_project_matrix(z, n_keep, group, use_pallas)
    v_new = v + rho * (w - d_new)
    mask_new = (d_new != 0.0).astype(jnp.float32)
    mask_old = (d != 0.0).astype(jnp.float32)
    delta = jnp.sum(jnp.abs(mask_new - mask_old))
    nnz = jnp.sum(mask_new)
    return w, d_new, v_new, delta[None], nnz[None]


# --------------------------------------------------------------------------
# PCG refinement (Algorithm 2)
# --------------------------------------------------------------------------

def pcg_refine(h, g, w0, mask, *, iters: int = 10, use_pallas: bool = False):
    """Solve min ||X What - X W||_F^2 s.t. supp(W) in S, via PCG.

    h    [n, n]  XtX
    g    [n, m]  XtX @ What
    w0   [n, m]  initial W (its entries outside the mask are zeroed)
    mask [n, m]  support indicator (1.0 inside S)

    Runs ``iters`` iterations of Algorithm 2 inside a fori_loop; returns
    (w, final residual Frobenius norm [1]).
    """
    diag = jnp.clip(jnp.diagonal(h), 1e-12, None)
    invdiag = (1.0 / diag).astype(jnp.float32)[:, None]

    w0 = w0 * mask
    r0 = (g - _dot(h, w0, use_pallas)) * mask
    z0 = invdiag * r0
    p0 = z0
    rz0 = jnp.sum(r0 * z0)

    def body(_, state):
        w, r, p, rz = state
        hp = _dot(h, p, use_pallas)
        denom = jnp.sum(p * hp)
        alpha = jnp.where(denom > 0.0, rz / jnp.maximum(denom, 1e-30), 0.0)
        if use_pallas:
            w_new, r_new, z_new = kpcg.pcg_elementwise(w, p, r, hp, mask, invdiag, alpha)
        else:
            w_new = w + alpha * p
            r_new = (r - alpha * hp) * mask
            z_new = invdiag * r_new
        rz_new = jnp.sum(r_new * z_new)
        beta = jnp.where(rz > 0.0, rz_new / jnp.maximum(rz, 1e-30), 0.0)
        p_new = z_new + beta * p
        return w_new, r_new, p_new, rz_new

    w, r, _, _ = lax.fori_loop(0, iters, body, (w0, r0, p0, rz0))
    res = jnp.sqrt(jnp.sum(r * r))
    return w, res[None]


# --------------------------------------------------------------------------
# gram: XtX and XtX @ What in one pass
# --------------------------------------------------------------------------

def gram(x, what, *, use_pallas: bool = False):
    """Return (H, G) = (XtX, XtX @ What) for x [rows, n], what [n, m]."""
    h = _dot(x.T, x, use_pallas)
    gmat = _dot(h, what, use_pallas)
    return h, gmat


# --------------------------------------------------------------------------
# tiny decoder-only transformer (the pruning target + perplexity evaluator)
# --------------------------------------------------------------------------

# Parameter layout: a flat ordered list of (name, shape) — the exact order
# used by aot.py when exporting model_fwd and by the rust weights loader.

def param_spec(cfg: Dict[str, Any]) -> List[Any]:
    d, ff, v, s = cfg["d_model"], cfg["d_ff"], cfg["vocab"], cfg["seq_len"]
    spec = [("tok_emb", (v, d)), ("pos_emb", (s, d))]
    for i in range(cfg["n_layers"]):
        p = f"blocks.{i}."
        spec += [
            (p + "ln1.g", (d,)), (p + "ln1.b", (d,)),
            (p + "attn.wq", (d, d)), (p + "attn.wk", (d, d)),
            (p + "attn.wv", (d, d)), (p + "attn.wo", (d, d)),
            (p + "ln2.g", (d,)), (p + "ln2.b", (d,)),
            (p + "mlp.w1", (d, ff)), (p + "mlp.w2", (ff, d)),
        ]
    spec += [("ln_f.g", (d,)), ("ln_f.b", (d,))]
    return spec


def init_params(cfg: Dict[str, Any], key) -> Dict[str, jnp.ndarray]:
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "pos_emb":
            params[name] = 0.01 * jax.random.normal(sub, shape, jnp.float32)
        else:
            scale = 1.0 / jnp.sqrt(jnp.asarray(shape[0], jnp.float32))
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo, n_heads: int):
    b, s, d = x.shape
    hd = d // n_heads

    def split(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    q = split(x @ wq)
    k = split(x @ wk)
    v = split(x @ wv)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    att = jnp.where(causal[None, None] > 0, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def forward(params: Dict[str, jnp.ndarray], ids, cfg: Dict[str, Any]):
    """Logits [batch, seq, vocab] for token ids [batch, seq] (i32)."""
    b, s = ids.shape
    x = params["tok_emb"][ids] + params["pos_emb"][None, :s]
    for i in range(cfg["n_layers"]):
        p = f"blocks.{i}."
        h = _layer_norm(x, params[p + "ln1.g"], params[p + "ln1.b"])
        x = x + _attention(h, params[p + "attn.wq"], params[p + "attn.wk"],
                           params[p + "attn.wv"], params[p + "attn.wo"],
                           cfg["n_heads"])
        h = _layer_norm(x, params[p + "ln2.g"], params[p + "ln2.b"])
        x = x + jax.nn.gelu(h @ params[p + "mlp.w1"]) @ params[p + "mlp.w2"]
    x = _layer_norm(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["tok_emb"].T  # tied unembedding


def nll_positions(params, ids, cfg):
    """Per-position next-token NLL [batch, seq-1] (natural log)."""
    logits = forward(params, ids, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll


def loss_fn(params, ids, cfg):
    return jnp.mean(nll_positions(params, ids, cfg))


# model presets (kept in sync with rust/src/config/presets.rs)
PRESETS: Dict[str, Dict[str, Any]] = {
    "alps-tiny": dict(d_model=128, d_ff=512, n_layers=2, n_heads=4,
                      vocab=512, seq_len=128),
    "alps-small": dict(d_model=192, d_ff=768, n_layers=4, n_heads=6,
                       vocab=512, seq_len=128),
    "alps-base": dict(d_model=256, d_ff=1024, n_layers=6, n_heads=8,
                      vocab=512, seq_len=128),
}


def prunable_shapes(cfg: Dict[str, Any]) -> List[Any]:
    """Distinct (n_in, n_out) shapes of prunable linear layers."""
    d, ff = cfg["d_model"], cfg["d_ff"]
    return [(d, d), (d, ff), (ff, d)]


def n_params(cfg: Dict[str, Any]) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_spec(cfg))
