"""Smoke-test export: exercise the riskiest HLO constructs we rely on
(sort/top_k for projection, dynamic_slice with a *runtime* scalar index for
sparsity-k thresholding, fori_loop/while for PCG, pallas interpret kernels)
through the stablehlo -> XlaComputation -> HLO-text path that the rust
runtime consumes.

Usage: python -m compile.smoke_export ../artifacts/smoke.hlo.txt
"""
import sys

import jax
import jax.numpy as jnp
from jax import lax
from jax._src.lib import xla_client as xc


def smoke_fn(a, b, k):
    """a, b: f32[4,6]; k: i32 scalar (runtime).

    Returns a tuple exercising: matmul, sort-descending, dynamic_slice with
    runtime index, top-k-style mask via threshold, and a fori_loop.
    """
    # matmul
    c = a @ b.T  # [4,4]
    # global magnitude sort (descending) of |a|
    flat = jnp.sort(jnp.abs(a).reshape(-1))[::-1]
    # runtime-k threshold: value of the k-th largest entry
    thresh = lax.dynamic_slice(flat, (k - 1,), (1,))[0]
    mask = (jnp.abs(a) >= thresh).astype(jnp.float32)
    proj = a * mask
    # fori_loop: 5 steps of y <- 0.5*y + c
    y0 = jnp.zeros_like(c)
    y = lax.fori_loop(0, 5, lambda i, y: 0.5 * y + c, y0)
    return c, proj, y, jnp.sum(mask)[None]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "/tmp/smoke.hlo.txt"
    spec = jax.ShapeDtypeStruct((4, 6), jnp.float32)
    kspec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(smoke_fn).lower(spec, spec, kspec)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {out_path}")


if __name__ == "__main__":
    main()
