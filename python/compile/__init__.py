"""Build-time compile path for ALPS: pallas kernels, jax graphs, AOT export.

Nothing in this package runs on the request path — ``make artifacts``
invokes it once and the rust binary is self-contained afterwards.
"""
