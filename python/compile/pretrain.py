"""Build-time pretraining of the pruning-target models (tiny GPTs).

Trains each preset on the synthetic corpus with a hand-rolled Adam (optax is
not available offline), then serializes weights in the ALPS binary format
consumed by ``rust/src/model/weights.rs``:

    magic "ALPSMDL1" | u32 n_tensors |
    per tensor: u32 name_len | name | u32 ndim | u32 dims... | f32 LE data

Also writes the corpus artifacts (vocab + token id splits) as
``artifacts/corpus.bin``:

    magic "ALPSCRP1" | u32 vocab_size | per word: u32 len | bytes |
    u32 n_splits | per split: u32 name_len | name | u32 n_tokens | u16 ids

Run via ``make artifacts`` (cached: skipped when outputs are newer).
"""
import argparse
import struct
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod
from . import model as model_mod


# --------------------------------------------------------------------------
# serialization
# --------------------------------------------------------------------------

def write_model_bin(path: str, params: Dict[str, jnp.ndarray], spec) -> None:
    with open(path, "wb") as f:
        f.write(b"ALPSMDL1")
        f.write(struct.pack("<I", len(spec)))
        for name, _shape in spec:
            t = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", t.ndim))
            for d in t.shape:
                f.write(struct.pack("<I", d))
            f.write(t.tobytes())


def write_corpus_bin(path: str, built: Dict) -> None:
    vocab: Dict[str, int] = built["vocab"]
    inv = [None] * len(vocab)
    for w, i in vocab.items():
        inv[i] = w
    with open(path, "wb") as f:
        f.write(b"ALPSCRP1")
        f.write(struct.pack("<I", len(inv)))
        for w in inv:
            wb = w.encode()
            f.write(struct.pack("<I", len(wb)))
            f.write(wb)
        splits = built["splits"]
        f.write(struct.pack("<I", len(splits)))
        for name in sorted(splits.keys()):
            ids = splits[name]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", len(ids)))
            f.write(np.asarray(ids, dtype=np.uint16).tobytes())


def write_model_json(path: str, name: str, cfg: Dict) -> None:
    with open(path, "w") as f:
        f.write("{\n")
        f.write(f'  "name": "{name}",\n')
        keys = ["d_model", "d_ff", "n_layers", "n_heads", "vocab", "seq_len"]
        parts = [f'  "{k}": {cfg[k]}' for k in keys]
        f.write(",\n".join(parts))
        f.write("\n}\n")


# --------------------------------------------------------------------------
# training (hand-rolled Adam)
# --------------------------------------------------------------------------

def batches(ids: np.ndarray, seq_len: int, batch: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(ids) - seq_len - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        yield np.stack([ids[s: s + seq_len] for s in starts]).astype(np.int32)


def train_model(name: str, cfg: Dict, train_ids: np.ndarray, steps: int,
                batch: int, lr: float, seed: int):
    key = jax.random.PRNGKey(seed)
    params = model_mod.init_params(cfg, key)
    spec = model_mod.param_spec(cfg)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, ids: model_mod.loss_fn(p, ids, cfg)))

    # Adam state
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step(params, m, v, ids, t):
        loss, grads = loss_grad(params, ids)
        new_p, new_m, new_v = {}, {}, {}
        for k in params:
            new_m[k] = b1 * m[k] + (1 - b1) * grads[k]
            new_v[k] = b2 * v[k] + (1 - b2) * grads[k] ** 2
            mhat = new_m[k] / (1 - b1 ** t)
            vhat = new_v[k] / (1 - b2 ** t)
            new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v, loss

    t0 = time.time()
    losses = []
    for i, ids in enumerate(batches(train_ids, cfg["seq_len"], batch, steps, seed)):
        params, m, v, loss = step(params, m, v, jnp.asarray(ids),
                                  jnp.asarray(i + 1, jnp.float32))
        losses.append(float(loss))
        if (i + 1) % 50 == 0:
            print(f"  [{name}] step {i + 1}/{steps} loss={losses[-1]:.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    print(f"  [{name}] final loss {losses[-1]:.4f} "
          f"(start {losses[0]:.4f}) in {time.time() - t0:.1f}s", flush=True)
    return params, spec, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="alps-tiny,alps-small,alps-base")
    ap.add_argument("--steps", type=int, default=0, help="override steps for all")
    args = ap.parse_args()

    print("building corpus ...", flush=True)
    built = corpus_mod.build_all()
    write_corpus_bin(f"{args.out_dir}/corpus.bin", built)
    train_ids = np.asarray(built["splits"]["train"], dtype=np.int64)
    print(f"corpus: vocab={len(built['vocab'])} "
          f"train={len(train_ids)} tokens", flush=True)

    schedule = {
        "alps-tiny": dict(steps=400, batch=16, lr=1e-3, seed=7),
        "alps-small": dict(steps=300, batch=16, lr=8e-4, seed=11),
        "alps-base": dict(steps=250, batch=12, lr=6e-4, seed=13),
    }
    for name in args.models.split(","):
        cfg = model_mod.PRESETS[name]
        sch = dict(schedule[name])
        if args.steps:
            sch["steps"] = args.steps
        print(f"training {name}: {model_mod.n_params(cfg):,} params, "
              f"{sch}", flush=True)
        params, spec, _ = train_model(name, cfg, train_ids, **sch)
        write_model_bin(f"{args.out_dir}/model_{name}.bin", params, spec)
        write_model_json(f"{args.out_dir}/model_{name}.json", name, cfg)
        print(f"wrote {args.out_dir}/model_{name}.bin", flush=True)


if __name__ == "__main__":
    sys.exit(main())
