"""AOT export: lower every Layer-2 graph to HLO text for the rust runtime.

Interchange format is HLO *text* (never ``.serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (verified by ``alps smoke``).

Exported artifacts (``artifacts/*.hlo.txt``) + a manifest
(``artifacts/manifest.json``) describing each artifact's ordered inputs and
outputs so the rust side can marshal literals without guessing.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""
import argparse
import sys
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod

F32 = jnp.float32
I32 = jnp.int32

# calibration geometry (kept in sync with rust/src/config/presets.rs)
CALIB_SEQS = 32
SEQ_LEN = 128
CALIB_ROWS = CALIB_SEQS * SEQ_LEN
EVAL_BATCH = 8
PCG_ITERS = 10


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape: Sequence[int], dtype=F32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: List[Dict[str, Any]] = []

    def export(self, name: str, fn, in_specs: List[Tuple[str, Sequence[int], str]],
               outputs: List[Tuple[str, Sequence[int]]], kind: str) -> None:
        """Lower ``fn`` with the given input specs and write HLO text."""
        specs = []
        for _, shp, dt in in_specs:
            specs.append(spec(shp, I32 if dt == "i32" else F32))
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = f"{self.out_dir}/{name}.hlo.txt"
        with open(path, "w") as f:
            f.write(text)
        self.manifest.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "kind": kind,
            "inputs": [{"name": n, "shape": list(s), "dtype": d}
                       for n, s, d in in_specs],
            "outputs": [{"name": n, "shape": list(s)} for n, s in outputs],
        })
        print(f"  exported {name} ({len(text)} chars)", flush=True)

    def write_manifest(self) -> None:
        # hand-rolled json (matches the rust config::json parser subset)
        def jstr(s: str) -> str:
            return '"' + s.replace("\\", "\\\\").replace('"', '\\"') + '"'

        lines = ["["]
        for i, ent in enumerate(self.manifest):
            lines.append("  {")
            lines.append(f'    "name": {jstr(ent["name"])},')
            lines.append(f'    "file": {jstr(ent["file"])},')
            lines.append(f'    "kind": {jstr(ent["kind"])},')
            for key in ("inputs", "outputs"):
                items = []
                for io in ent[key]:
                    shape = ",".join(str(x) for x in io["shape"])
                    dt = io.get("dtype", "f32")
                    items.append('{"name": %s, "shape": [%s], "dtype": %s}'
                                 % (jstr(io["name"]), shape, jstr(dt)))
                sep = "," if key == "inputs" else ""
                lines.append(f'    "{key}": [' + ", ".join(items) + f"]{sep}")
            lines.append("  }" + ("," if i + 1 < len(self.manifest) else ""))
        lines.append("]")
        with open(f"{self.out_dir}/manifest.json", "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"  wrote manifest.json ({len(self.manifest)} artifacts)", flush=True)


def admm_shapes() -> List[Tuple[int, int]]:
    shapes = []
    for cfg in model_mod.PRESETS.values():
        for s in model_mod.prunable_shapes(cfg):
            if s not in shapes:
                shapes.append(s)
    # the Fig.2 / Table 1 single-layer experiment shape
    if (512, 512) not in shapes:
        shapes.append((512, 512))
    return shapes


def export_admm(ex: Exporter, use_pallas: bool = False) -> None:
    for (n, m) in admm_shapes():
        suffix = "_pallas" if use_pallas else ""
        ex.export(
            f"admm_iter{suffix}_{n}x{m}",
            lambda q, me, g, d, v, rho, k, _up=use_pallas: model_mod.admm_iter(
                q, me, g, d, v, rho, k, use_pallas=_up),
            [("q", (n, n), "f32"), ("m_eig", (n,), "f32"), ("g", (n, m), "f32"),
             ("d", (n, m), "f32"), ("v", (n, m), "f32"), ("rho", (), "f32"),
             ("k", (), "i32")],
            [("w", (n, m)), ("d_new", (n, m)), ("v_new", (n, m)),
             ("delta", (1,)), ("nnz", (1,))],
            "admm_iter",
        )


def export_admm_nm(ex: Exporter) -> None:
    cfg = model_mod.PRESETS["alps-base"]
    patterns = [(2, 4), (4, 8)]
    for (n, m) in model_mod.prunable_shapes(cfg):
        for (nk, grp) in patterns:
            ex.export(
                f"admm_iter_nm{nk}of{grp}_{n}x{m}",
                lambda q, me, g, d, v, rho, _nk=nk, _g=grp: model_mod.admm_iter_nm(
                    q, me, g, d, v, rho, n_keep=_nk, group=_g),
                [("q", (n, n), "f32"), ("m_eig", (n,), "f32"),
                 ("g", (n, m), "f32"), ("d", (n, m), "f32"),
                 ("v", (n, m), "f32"), ("rho", (), "f32")],
                [("w", (n, m)), ("d_new", (n, m)), ("v_new", (n, m)),
                 ("delta", (1,)), ("nnz", (1,))],
                "admm_iter_nm",
            )


def export_pcg(ex: Exporter) -> None:
    for (n, m) in admm_shapes():
        ex.export(
            f"pcg_refine_{n}x{m}",
            lambda h, g, w0, mask: model_mod.pcg_refine(
                h, g, w0, mask, iters=PCG_ITERS),
            [("h", (n, n), "f32"), ("g", (n, m), "f32"),
             ("w0", (n, m), "f32"), ("mask", (n, m), "f32")],
            [("w", (n, m)), ("res", (1,))],
            "pcg_refine",
        )


def export_gram(ex: Exporter) -> None:
    seen = set()
    for cfg in model_mod.PRESETS.values():
        for (n, m) in model_mod.prunable_shapes(cfg):
            if (n, m) in seen:
                continue
            seen.add((n, m))
            ex.export(
                f"gram_{CALIB_ROWS}x{n}_{m}",
                lambda x, w: model_mod.gram(x, w),
                [("x", (CALIB_ROWS, n), "f32"), ("what", (n, m), "f32")],
                [("h", (n, n)), ("g", (n, m))],
                "gram",
            )
    # Fig.2 shape
    n = m = 512
    ex.export(
        f"gram_{CALIB_ROWS}x{n}_{m}",
        lambda x, w: model_mod.gram(x, w),
        [("x", (CALIB_ROWS, n), "f32"), ("what", (n, m), "f32")],
        [("h", (n, n)), ("g", (n, m))],
        "gram",
    )


def export_model_fwd(ex: Exporter) -> None:
    for name, cfg in model_mod.PRESETS.items():
        pspec = model_mod.param_spec(cfg)

        def fwd(ids, *flat, _cfg=cfg, _spec=pspec):
            params = {n: t for (n, _), t in zip(_spec, flat)}
            return (model_mod.nll_positions(params, ids, _cfg),)

        in_specs: List[Tuple[str, Sequence[int], str]] = [
            ("ids", (EVAL_BATCH, cfg["seq_len"]), "i32")]
        for pname, shape in pspec:
            in_specs.append((pname, shape, "f32"))
        ex.export(
            f"model_fwd_{name}",
            fwd,
            in_specs,
            [("nll", (EVAL_BATCH, cfg["seq_len"] - 1))],
            "model_fwd",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-pallas", action="store_true",
                    help="skip the pallas-variant demo artifact")
    args = ap.parse_args()
    ex = Exporter(args.out_dir)
    print("exporting ADMM iteration graphs ...", flush=True)
    export_admm(ex)
    print("exporting N:M ADMM graphs ...", flush=True)
    export_admm_nm(ex)
    print("exporting PCG refinement graphs ...", flush=True)
    export_pcg(ex)
    print("exporting gram graphs ...", flush=True)
    export_gram(ex)
    print("exporting model forward graphs ...", flush=True)
    export_model_fwd(ex)
    if not args.skip_pallas:
        print("exporting pallas-variant demo artifact ...", flush=True)
        n, m = 128, 128
        ex.export(
            f"admm_iter_pallas_{n}x{m}",
            lambda q, me, g, d, v, rho, k: model_mod.admm_iter(
                q, me, g, d, v, rho, k, use_pallas=True),
            [("q", (n, n), "f32"), ("m_eig", (n,), "f32"), ("g", (n, m), "f32"),
             ("d", (n, m), "f32"), ("v", (n, m), "f32"), ("rho", (), "f32"),
             ("k", (), "i32")],
            [("w", (n, m)), ("d_new", (n, m)), ("v_new", (n, m)),
             ("delta", (1,)), ("nnz", (1,))],
            "admm_iter",
        )
    ex.write_manifest()
    print("AOT export complete.", flush=True)


if __name__ == "__main__":
    sys.exit(main())
