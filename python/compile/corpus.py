"""Deterministic synthetic corpus (build-time).

The paper calibrates on C4 and evaluates perplexity on WikiText2 / PTB / C4.
Those corpora are unavailable here, so we synthesize a language with enough
statistical structure that (a) a small transformer learns non-trivial
weights/activations and (b) pruning damage shows up as a perplexity
increase: a hand-written seed text expanded by an order-2 word-level Markov
chain, with three held-out "datasets" generated at different temperatures /
seeds standing in for WikiText2 / PTB / C4 (see DESIGN.md §Substitutions).

Everything is deterministic given the seed (splitmix64 PRNG, no
python-random), so `make artifacts` is reproducible bit-for-bit.
"""
from typing import Dict, List, Tuple

SEED_TEXT = """
the model compresses the network by removing redundant weights from each
layer . the pruning problem asks for a sparse weight matrix that minimizes
the reconstruction error between the dense output and the pruned output .
the operator splitting technique decomposes the hard problem into two
friendly subproblems that exchange information through a penalty term .
the first subproblem solves a ridge regression and the second subproblem
projects the weights onto the sparse set . the dual variable keeps the two
copies consistent as the iterations proceed . when the penalty grows the
support stabilizes and the conjugate gradient method refines the weights on
the frozen support . the preconditioner scales the residual by the inverse
diagonal of the gram matrix so the iteration converges in a few steps .
the calibration data flows through the network layer by layer and each
layer observes the activations produced by the previously pruned layers .
a large language model stores billions of parameters and the memory cost
limits the deployment on modest hardware . sparsity reduces the storage and
can accelerate the inference when the pattern matches the hardware .
magnitude pruning keeps the largest weights but ignores the correlation
between the inputs . the second order methods consider the curvature of the
loss and compensate the removed weights by updating the survivors . the
hessian of the layerwise objective equals the gram matrix of the input
activations . the eigendecomposition of the gram matrix allows the solver
to reuse the factorization when the penalty parameter changes . a good
support contains the weights that contribute the most to the output and the
optimization finds combinations that the simple heuristics miss . at high
sparsity the gap between the heuristic and the optimized solution widens
because the interactions between the weights dominate the objective . the
structured pattern keeps two weights in every group of four and the
hardware multiplies the sparse matrix efficiently . the perplexity measures
how well the model predicts the held out text and a lower value indicates a
better model . the zero shot benchmark asks the model to choose the more
plausible continuation and the accuracy reflects the remaining capability .
the experiments sweep the sparsity from forty to ninety percent and report
the mean and the deviation over five runs . the algorithm runs on a single
accelerator and prunes the largest model within a few hours . the theory
guarantees that the iterates converge when the penalty sequence grows fast
enough and the proof bounds the distance between the two copies by a
constant over the penalty . the ablation fixes the support found by each
method and solves the restricted problem to optimality so the comparison
isolates the quality of the support . the vectorized solver processes all
the columns in a single pass and the graphics processor multiplies the
matrices in parallel . the speedup over the naive backsolve reaches two
hundred when the sparsity is moderate . the future work extends the
framework to structured pruning and quantization . the language model
generates text by sampling the next token from the predicted distribution .
the attention mechanism mixes information across the positions and the
feed forward network transforms each position independently . the residual
stream carries the signal through the blocks and the layer normalization
stabilizes the activations . the embedding maps the tokens to vectors and
the unembedding projects the vectors back to the vocabulary . the training
minimizes the cross entropy and the optimizer adapts the learning rate for
each parameter . the gradient flows backward through the layers and the
chain rule multiplies the local derivatives . the deep network learns the
hierarchical features and the width controls the capacity of each layer .
""".split()


class SplitMix64:
    """Tiny deterministic PRNG (same constants as the rust util::rng)."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def uniform(self) -> float:
        return self.next_u64() / float(1 << 64)

    def choice_weighted(self, items: List, weights: List[float]):
        total = sum(weights)
        r = self.uniform() * total
        acc = 0.0
        for it, w in zip(items, weights):
            acc += w
            if r <= acc:
                return it
        return items[-1]


def build_chain(words: List[str]) -> Dict[Tuple[str, str], Dict[str, int]]:
    chain: Dict[Tuple[str, str], Dict[str, int]] = {}
    for i in range(len(words) - 2):
        key = (words[i], words[i + 1])
        nxt = words[i + 2]
        chain.setdefault(key, {})
        chain[key][nxt] = chain[key].get(nxt, 0) + 1
    return chain


def generate(n_tokens: int, seed: int, temperature: float = 1.0) -> List[str]:
    """Generate ``n_tokens`` words from the order-2 chain.

    ``temperature`` reshapes the transition counts (w**(1/T)); lower T makes
    text closer to the seed (PTB-like regularity), higher T adds entropy
    (C4-like diversity).
    """
    rng = SplitMix64(seed)
    chain = build_chain(SEED_TEXT)
    keys = sorted(chain.keys())
    state = keys[rng.next_u64() % len(keys)]
    out = [state[0], state[1]]
    inv_t = 1.0 / max(temperature, 1e-6)
    while len(out) < n_tokens:
        succ = chain.get(state)
        if not succ:
            state = keys[rng.next_u64() % len(keys)]
            out.extend([state[0], state[1]])
            continue
        items = sorted(succ.keys())
        weights = [float(succ[w]) ** inv_t for w in items]
        nxt = rng.choice_weighted(items, weights)
        out.append(nxt)
        state = (state[1], nxt)
    return out[:n_tokens]


def build_vocab(words: List[str], size: int = 512) -> Dict[str, int]:
    """Word-level vocab: <pad>=0, <unk>=1, then by frequency (stable)."""
    freq: Dict[str, int] = {}
    for w in words:
        freq[w] = freq.get(w, 0) + 1
    ordered = sorted(freq.keys(), key=lambda w: (-freq[w], w))
    vocab = {"<pad>": 0, "<unk>": 1}
    for w in ordered[: size - 2]:
        vocab[w] = len(vocab)
    return vocab


def encode(words: List[str], vocab: Dict[str, int]) -> List[int]:
    unk = vocab["<unk>"]
    return [vocab.get(w, unk) for w in words]


# the three eval "datasets" (names mirror the paper's benchmarks)
DATASETS = {
    "train": dict(seed=0x5EED_0001, temperature=1.0, n_tokens=240_000),
    "wikitext2-like": dict(seed=0x5EED_1001, temperature=1.0, n_tokens=24_000),
    "ptb-like": dict(seed=0x5EED_2002, temperature=0.7, n_tokens=24_000),
    "c4-like": dict(seed=0x5EED_3003, temperature=1.4, n_tokens=24_000),
}


def build_all() -> Dict[str, object]:
    """Generate vocab + every split. Returns {vocab, splits: {name: ids}}."""
    train_words = generate(**DATASETS["train"])
    vocab = build_vocab(train_words)
    splits = {"train": encode(train_words, vocab)}
    for name, cfg in DATASETS.items():
        if name == "train":
            continue
        splits[name] = encode(generate(**cfg), vocab)
    return {"vocab": vocab, "splits": splits}
