"""Layer-2 graph tests: ADMM iteration semantics, PCG refinement, the
transformer forward, and the Theorem-1 convergence bound."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=15, deadline=None)


def layer_problem(n=24, m=12, rows=80, seed=0):
    rng = np.random.RandomState(seed)
    x = rng.randn(rows, n).astype(np.float32)
    what = rng.randn(n, m).astype(np.float32)
    h = x.T @ x
    return x, what, h, h @ what


def scaled(h, g, what):
    """Paper B.1 preprocessing: unit-diagonal gram."""
    e = 1.0 / np.sqrt(np.diag(h))
    hs = (h * e[:, None]) * e[None, :]
    gs = g * e[:, None]
    whats = what / e[:, None]
    return hs, gs, whats, e


def run_alps(x, what, h, g, sparsity, max_iters=600, pcg_iters=10):
    hs, gs, whats, e = scaled(h, g, what)
    evals, q = np.linalg.eigh(hs)
    n, m = what.shape
    k = int((1.0 - sparsity) * n * m)
    d, v = whats.copy(), np.zeros_like(what)
    rho, t = 0.1, 0
    prev = d != 0
    while t < max_iters:
        for _ in range(3):
            w, d, v, delta, nnz = M.admm_iter(
                jnp.asarray(q), jnp.asarray(evals), jnp.asarray(gs),
                jnp.asarray(d), jnp.asarray(v), jnp.float32(rho), jnp.int32(k))
            w, d, v = map(np.asarray, (w, d, v))
            t += 1
        supp = d != 0
        s_t = np.sum(supp != prev)
        prev = supp
        if s_t >= 0.1 * k:
            rho *= 1.3
        elif s_t >= 0.005 * k:
            rho *= 1.2
        elif s_t >= 1:
            rho *= 1.1
        else:
            break
    mask = (d != 0).astype(np.float32)
    wr, _ = M.pcg_refine(jnp.asarray(hs), jnp.asarray(gs), jnp.asarray(d),
                         jnp.asarray(mask), iters=pcg_iters)
    return np.asarray(wr) * e[:, None], k


def rel_err(x, what, w):
    return (np.linalg.norm(x @ what - x @ w) ** 2
            / np.linalg.norm(x @ what) ** 2)


# ------------------------------------------------------------------ ADMM

def test_admm_iter_nnz_exact():
    x, what, h, g = layer_problem()
    evals, q = np.linalg.eigh(h)
    k = 100
    w, d, v, delta, nnz = M.admm_iter(
        jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
        jnp.asarray(what), jnp.asarray(np.zeros_like(what)),
        jnp.float32(1.0), jnp.int32(k))
    assert int(nnz[0]) == k
    assert np.count_nonzero(np.asarray(d)) == k


def test_admm_w_update_solves_ridge():
    """W-update must equal (H + rho I)^-1 (G - V + rho D)."""
    x, what, h, g = layer_problem(n=16, m=8)
    evals, q = np.linalg.eigh(h)
    rng = np.random.RandomState(3)
    d = rng.randn(16, 8).astype(np.float32)
    v = rng.randn(16, 8).astype(np.float32)
    rho = 2.5
    w, *_ = M.admm_iter(jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
                        jnp.asarray(d), jnp.asarray(v), jnp.float32(rho),
                        jnp.int32(64))
    expect = np.linalg.solve(h + rho * np.eye(16), g - v + rho * d)
    np.testing.assert_allclose(np.asarray(w), expect, rtol=2e-3, atol=2e-3)


def test_admm_delta_support_counts_changes():
    x, what, h, g = layer_problem(n=16, m=8)
    evals, q = np.linalg.eigh(h)
    z = np.zeros_like(what)
    # starting from D=0 (empty support), delta = k new entries
    _, d, _, delta, _ = M.admm_iter(
        jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
        jnp.asarray(z), jnp.asarray(z), jnp.float32(1.0), jnp.int32(40))
    assert int(delta[0]) == 40


def test_admm_beats_magnitude_pruning():
    x, what, h, g = layer_problem(n=32, m=16, rows=100)
    w_alps, k = run_alps(x, what, h, g, sparsity=0.7)
    flat = np.sort(np.abs(what).ravel())[::-1]
    wmp = what * (np.abs(what) >= flat[k - 1])
    assert rel_err(x, what, w_alps) < rel_err(x, what, wmp)


@settings(**SETTINGS)
@given(seed=st.integers(0, 100), sparsity=st.sampled_from([0.5, 0.7, 0.8]))
def test_admm_sparsity_respected(seed, sparsity):
    x, what, h, g = layer_problem(n=16, m=8, rows=60, seed=seed)
    w, k = run_alps(x, what, h, g, sparsity, max_iters=120)
    assert np.count_nonzero(w) <= k


def test_theorem1_residual_bound():
    """Theorem 1: ||W(t+1) - D(t+1)||_F <= C / rho_t for geometric rho."""
    x, what, h, g = layer_problem(n=20, m=10)
    hs, gs, whats, e = scaled(h, g, what)
    evals, q = np.linalg.eigh(hs)
    k = 60
    d, v = whats.copy(), np.zeros_like(what)
    rho = 1.0
    gaps, rhos = [], []
    for t in range(40):
        w, d, v, *_ = M.admm_iter(
            jnp.asarray(q), jnp.asarray(evals), jnp.asarray(gs),
            jnp.asarray(d), jnp.asarray(v), jnp.float32(rho), jnp.int32(k))
        w, d, v = map(np.asarray, (w, d, v))
        gaps.append(np.linalg.norm(w - d))
        rhos.append(rho)
        rho *= 1.25  # geometric => sum 1/rho_t < inf
    # gap * rho must stay bounded (C exists)
    prods = [gap * r for gap, r in zip(gaps[5:], rhos[5:])]
    assert max(prods) < 50 * np.median(prods) + 1e3
    # and the primal gap itself must vanish
    assert gaps[-1] < 1e-2 * (gaps[0] + 1e-9) + 1e-4


# ------------------------------------------------------------------ N:M

def test_admm_nm_respects_pattern():
    x, what, h, g = layer_problem(n=16, m=8)
    evals, q = np.linalg.eigh(h)
    z = np.zeros_like(what)
    _, d, _, _, nnz = M.admm_iter_nm(
        jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
        jnp.asarray(what), jnp.asarray(z), jnp.float32(1.0),
        n_keep=2, group=4)
    d = np.asarray(d)
    assert int(nnz[0]) <= 16 * 8 // 2
    # check the pattern: along each column, groups of 4 have <= 2 nz
    for j in range(8):
        col = d[:, j]
        for gstart in range(0, 16, 4):
            assert np.count_nonzero(col[gstart:gstart + 4]) <= 2


# ------------------------------------------------------------------ PCG

def test_pcg_refine_matches_dense_solve():
    """On a full support, PCG must approach the unconstrained solution."""
    x, what, h, g = layer_problem(n=16, m=8)
    hs, gs, whats, e = scaled(h, g, what)
    mask = np.ones_like(what)
    w, res = M.pcg_refine(jnp.asarray(hs), jnp.asarray(gs),
                          jnp.asarray(np.zeros_like(what)),
                          jnp.asarray(mask), iters=60)
    w = np.asarray(w) * e[:, None]
    np.testing.assert_allclose(x @ w, x @ what, rtol=1e-2, atol=1e-2)


def test_pcg_refine_reduces_error_on_mp_support():
    x, what, h, g = layer_problem(n=32, m=16, rows=100)
    hs, gs, whats, e = scaled(h, g, what)
    k = 150
    flat = np.sort(np.abs(whats).ravel())[::-1]
    mask = (np.abs(whats) >= flat[k - 1]).astype(np.float32)
    w0 = whats * mask
    before = rel_err(x, what, w0 * e[:, None])
    w, _ = M.pcg_refine(jnp.asarray(hs), jnp.asarray(gs), jnp.asarray(w0),
                        jnp.asarray(mask), iters=10)
    after = rel_err(x, what, np.asarray(w) * e[:, None])
    assert after < before


def test_pcg_refine_preserves_support():
    x, what, h, g = layer_problem(n=16, m=8)
    mask = (np.random.RandomState(0).rand(16, 8) > 0.6).astype(np.float32)
    w, _ = M.pcg_refine(jnp.asarray(h), jnp.asarray(g),
                        jnp.asarray(what * mask), jnp.asarray(mask), iters=10)
    w = np.asarray(w)
    assert np.count_nonzero(w * (1 - mask)) == 0


def test_pcg_zero_mask_returns_zero():
    x, what, h, g = layer_problem(n=8, m=4)
    mask = np.zeros_like(what)
    w, res = M.pcg_refine(jnp.asarray(h), jnp.asarray(g),
                          jnp.asarray(what), jnp.asarray(mask), iters=5)
    assert np.count_nonzero(np.asarray(w)) == 0


# ------------------------------------------------------------------ gram

def test_gram_matches_numpy():
    x, what, h, g = layer_problem(n=16, m=8)
    hh, gg = M.gram(jnp.asarray(x), jnp.asarray(what))
    np.testing.assert_allclose(np.asarray(hh), h, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gg), g, rtol=1e-4)


# ------------------------------------------------------------ transformer

@pytest.fixture(scope="module")
def tiny_cfg():
    return dict(d_model=32, d_ff=64, n_layers=2, n_heads=4, vocab=64,
                seq_len=16)


def test_forward_shapes(tiny_cfg):
    params = M.init_params(tiny_cfg, jax.random.PRNGKey(0))
    ids = jnp.zeros((2, 16), jnp.int32)
    logits = M.forward(params, ids, tiny_cfg)
    assert logits.shape == (2, 16, 64)


def test_nll_positions_shape_and_positive(tiny_cfg):
    params = M.init_params(tiny_cfg, jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    nll = M.nll_positions(params, ids, tiny_cfg)
    assert nll.shape == (2, 15)
    assert (np.asarray(nll) > 0).all()


def test_forward_is_causal(tiny_cfg):
    """Changing a future token must not change past logits."""
    params = M.init_params(tiny_cfg, jax.random.PRNGKey(0))
    ids1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 64)
    ids2 = ids1.at[0, 10].set((ids1[0, 10] + 1) % 64)
    l1 = np.asarray(M.forward(params, ids1, tiny_cfg))
    l2 = np.asarray(M.forward(params, ids2, tiny_cfg))
    np.testing.assert_allclose(l1[0, :10], l2[0, :10], rtol=1e-5, atol=1e-5)


def test_init_loss_near_uniform(tiny_cfg):
    params = M.init_params(tiny_cfg, jax.random.PRNGKey(0))
    ids = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    loss = float(M.loss_fn(params, ids, tiny_cfg))
    assert abs(loss - np.log(64)) < 1.0


def test_param_spec_counts(tiny_cfg):
    spec = M.param_spec(tiny_cfg)
    assert len(spec) == 2 + 2 * 10 + 2
    names = [n for n, _ in spec]
    assert len(set(names)) == len(names)


def test_prunable_shapes(tiny_cfg):
    assert M.prunable_shapes(tiny_cfg) == [(32, 32), (32, 64), (64, 32)]


def test_presets_heads_divide_dmodel():
    for cfg in M.PRESETS.values():
        assert cfg["d_model"] % cfg["n_heads"] == 0
        assert cfg["vocab"] == 512 and cfg["seq_len"] == 128
