"""AOT export path: HLO text generation, manifest structure, binary formats."""
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M
from compile import pretrain


def test_to_hlo_text_basic():
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_admm_shapes_cover_all_presets():
    shapes = aot.admm_shapes()
    for cfg in M.PRESETS.values():
        for s in M.prunable_shapes(cfg):
            assert s in shapes
    assert (512, 512) in shapes


def test_exporter_writes_artifact_and_manifest():
    with tempfile.TemporaryDirectory() as td:
        ex = aot.Exporter(td)
        ex.export(
            "admm_iter_16x8",
            lambda q, me, g, d, v, rho, k: M.admm_iter(q, me, g, d, v, rho, k),
            [("q", (16, 16), "f32"), ("m_eig", (16,), "f32"),
             ("g", (16, 8), "f32"), ("d", (16, 8), "f32"),
             ("v", (16, 8), "f32"), ("rho", (), "f32"), ("k", (), "i32")],
            [("w", (16, 8)), ("d_new", (16, 8)), ("v_new", (16, 8)),
             ("delta", (1,)), ("nnz", (1,))],
            "admm_iter")
        ex.write_manifest()
        text = open(os.path.join(td, "admm_iter_16x8.hlo.txt")).read()
        assert "HloModule" in text
        man = open(os.path.join(td, "manifest.json")).read()
        assert '"admm_iter_16x8"' in man
        assert '"i32"' in man


def test_model_bin_roundtrip():
    cfg = dict(d_model=16, d_ff=32, n_layers=1, n_heads=2, vocab=32, seq_len=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = M.param_spec(cfg)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "m.bin")
        pretrain.write_model_bin(path, params, spec)
        with open(path, "rb") as f:
            assert f.read(8) == b"ALPSMDL1"
            (n_tensors,) = struct.unpack("<I", f.read(4))
            assert n_tensors == len(spec)
            # read first tensor fully
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            assert name == "tok_emb"
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            assert dims == (32, 16)
            data = np.frombuffer(f.read(4 * 32 * 16), dtype=np.float32)
            np.testing.assert_allclose(
                data.reshape(32, 16), np.asarray(params["tok_emb"]))


def test_corpus_bin_roundtrip():
    built = {"vocab": {"<pad>": 0, "<unk>": 1, "the": 2},
             "splits": {"train": [2, 1, 2, 0], "valid": [2, 2]}}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c.bin")
        pretrain.write_corpus_bin(path, built)
        with open(path, "rb") as f:
            assert f.read(8) == b"ALPSCRP1"
            (vs,) = struct.unpack("<I", f.read(4))
            assert vs == 3
            words = []
            for _ in range(vs):
                (ln,) = struct.unpack("<I", f.read(4))
                words.append(f.read(ln).decode())
            assert words == ["<pad>", "<unk>", "the"]
            (ns,) = struct.unpack("<I", f.read(4))
            assert ns == 2


def test_model_json(tmp_path=None):
    import tempfile as tf
    with tf.TemporaryDirectory() as td:
        p = os.path.join(td, "m.json")
        pretrain.write_model_json(p, "alps-tiny", M.PRESETS["alps-tiny"])
        text = open(p).read()
        assert '"d_model": 128' in text
        assert '"name": "alps-tiny"' in text
