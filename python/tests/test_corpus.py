"""Corpus generator determinism and statistical sanity."""
import numpy as np

from compile import corpus as C


def test_generation_deterministic():
    a = C.generate(500, seed=42)
    b = C.generate(500, seed=42)
    assert a == b


def test_generation_seed_sensitivity():
    assert C.generate(500, seed=1) != C.generate(500, seed=2)


def test_generation_length():
    assert len(C.generate(1234, seed=0)) == 1234


def test_vocab_has_specials():
    words = C.generate(5000, seed=0)
    vocab = C.build_vocab(words)
    assert vocab["<pad>"] == 0 and vocab["<unk>"] == 1
    assert len(vocab) <= 512


def test_encode_roundtrip_known_words():
    words = C.generate(5000, seed=0)
    vocab = C.build_vocab(words)
    ids = C.encode(words, vocab)
    assert len(ids) == len(words)
    assert max(ids) < len(vocab)
    assert min(ids) >= 0


def test_temperature_changes_entropy():
    """Higher temperature => higher unigram entropy (c4-like > ptb-like)."""
    def entropy(words):
        _, counts = np.unique(words, return_counts=True)
        p = counts / counts.sum()
        return -(p * np.log(p)).sum()

    low = C.generate(8000, seed=9, temperature=0.5)
    high = C.generate(8000, seed=9, temperature=2.0)
    assert entropy(high) > entropy(low)


def test_build_all_splits_present():
    built = C.build_all()
    assert set(built["splits"]) == {"train", "wikitext2-like", "ptb-like",
                                    "c4-like"}
    assert len(built["splits"]["train"]) == 240_000


def test_splitmix_matches_reference_vector():
    """Pin the PRNG so rust util::rng can share test vectors."""
    rng = C.SplitMix64(0)
    first = [rng.next_u64() for _ in range(3)]
    assert first == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]
