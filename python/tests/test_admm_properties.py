"""Hypothesis property sweeps over the Layer-2 ADMM/PCG graphs — the
python mirror of rust/tests/proptests.rs (same invariants, independent
implementation, so a violation on either side flags a spec divergence)."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=12, deadline=None)


def layer(n, m, rows, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(rows, n).astype(np.float32)
    what = rng.randn(n, m).astype(np.float32)
    h = x.T @ x
    return x, what, h, h @ what


@settings(**SETTINGS)
@given(n=st.integers(4, 24), m=st.integers(2, 10), seed=st.integers(0, 10_000),
       frac=st.floats(0.1, 0.9))
def test_admm_projection_exact_k(n, m, seed, frac):
    x, what, h, g = layer(n, m, n + 8, seed)
    evals, q = np.linalg.eigh(h)
    k = max(1, int(frac * n * m))
    _, d, _, _, nnz = M.admm_iter(
        jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
        jnp.asarray(what), jnp.asarray(np.zeros_like(what)),
        jnp.float32(1.0), jnp.int32(k))
    assert int(nnz[0]) == k
    assert np.count_nonzero(np.asarray(d)) == k


@settings(**SETTINGS)
@given(n=st.integers(4, 20), m=st.integers(2, 8), seed=st.integers(0, 10_000),
       rho=st.floats(0.05, 20.0))
def test_admm_w_update_solves_ridge(n, m, seed, rho):
    x, what, h, g = layer(n, m, n + 8, seed)
    evals, q = np.linalg.eigh(h)
    rng = np.random.RandomState(seed + 1)
    d = rng.randn(n, m).astype(np.float32)
    v = rng.randn(n, m).astype(np.float32)
    w, *_ = M.admm_iter(jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
                        jnp.asarray(d), jnp.asarray(v), jnp.float32(rho),
                        jnp.int32(n * m // 2))
    lhs = (h + rho * np.eye(n)) @ np.asarray(w)
    rhs = g - v + rho * d
    denom = np.linalg.norm(rhs) + 1e-6
    assert np.linalg.norm(lhs - rhs) / denom < 5e-3


@settings(**SETTINGS)
@given(n=st.integers(4, 16), m=st.integers(2, 6), seed=st.integers(0, 10_000))
def test_pcg_never_worse_than_start(n, m, seed):
    x, what, h, g = layer(n, m, n + 10, seed)
    rng = np.random.RandomState(seed + 2)
    mask = (rng.rand(n, m) > 0.5).astype(np.float32)
    w0 = what * mask

    def err(w):
        return float(np.linalg.norm(x @ what - x @ w) ** 2)

    w, _ = M.pcg_refine(jnp.asarray(h), jnp.asarray(g), jnp.asarray(w0),
                        jnp.asarray(mask), iters=10)
    assert err(np.asarray(w)) <= err(w0) + 1e-3


@settings(**SETTINGS)
@given(n=st.sampled_from([8, 16]), m=st.integers(2, 6),
       seed=st.integers(0, 10_000), pattern=st.sampled_from([(2, 4), (4, 8)]))
def test_admm_nm_group_budget(n, m, seed, pattern):
    nk, grp = pattern
    x, what, h, g = layer(n, m, n + 8, seed)
    evals, q = np.linalg.eigh(h)
    _, d, _, _, _ = M.admm_iter_nm(
        jnp.asarray(q), jnp.asarray(evals), jnp.asarray(g),
        jnp.asarray(what), jnp.asarray(np.zeros_like(what)),
        jnp.float32(1.0), n_keep=nk, group=grp)
    d = np.asarray(d)
    for j in range(m):
        col = d[:, j]
        for g0 in range(0, n, grp):
            assert np.count_nonzero(col[g0:g0 + grp]) <= nk


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 16))
def test_topk_threshold_consistent_with_exact(seed, n):
    rng = np.random.RandomState(seed)
    z = rng.randn(n, n).astype(np.float32)
    k = max(1, n * n // 3)
    thresh = float(M.topk_threshold(jnp.asarray(z), jnp.int32(k)))
    exact, _ = M.topk_project_exact(jnp.asarray(z), jnp.int32(k))
    kept = np.abs(np.asarray(exact)[np.asarray(exact) != 0])
    if kept.size:
        assert kept.min() >= thresh - 1e-6
