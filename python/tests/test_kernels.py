"""Kernel <-> oracle correctness: hypothesis sweeps over shapes/dtypes.

This is the CORE Layer-1 correctness signal: every pallas kernel must agree
with its pure-jnp oracle in compile.kernels.ref across randomized shapes.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as kmm
from compile.kernels import nm_project as knm
from compile.kernels import pcg_step as kpcg
from compile.kernels import ref
from compile.kernels import topk_mask as ktm

SETTINGS = dict(max_examples=25, deadline=None)


def rand(shape, seed, dtype=np.float32, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(dtype)


# ---------------------------------------------------------------- matmul

@settings(**SETTINGS)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    a = rand((m, k), seed)
    b = rand((k, n), seed + 1)
    np.testing.assert_allclose(
        np.asarray(kmm.matmul(a, b)), np.asarray(ref.matmul(a, b)),
        rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(m=st.sampled_from([32, 64, 128]), seed=st.integers(0, 1000))
def test_matmul_bfloat16_inputs_accumulate_f32(m, seed):
    import jax.numpy as jnp
    a = rand((m, m), seed).astype(jnp.bfloat16)
    b = rand((m, m), seed + 1).astype(jnp.bfloat16)
    out = np.asarray(kmm.matmul(a, b))
    assert out.dtype == np.float32
    expect = np.asarray(ref.matmul(a, b))
    np.testing.assert_allclose(out, expect, rtol=5e-2, atol=5e-2)


def test_matmul_identity():
    a = rand((16, 16), 0)
    np.testing.assert_allclose(
        np.asarray(kmm.matmul(a, np.eye(16, dtype=np.float32))), a, rtol=1e-6)


def test_matmul_block_divisor_picker():
    assert kmm._pick_block(128, 128) == 128
    assert kmm._pick_block(100, 64) == 50
    assert kmm._pick_block(7, 4) == 1
    assert kmm._pick_block(96, 128) == 96


def test_matmul_vmem_budget():
    # default tiles must fit VMEM with double-buffering headroom
    assert kmm.vmem_footprint_bytes(128, 128, 128) * 2 < 16 * 1024 * 1024


def test_matmul_mxu_estimate_monotone():
    assert kmm.mxu_utilization_estimate(128, 128, 128) > \
        kmm.mxu_utilization_estimate(8, 8, 8)


# ---------------------------------------------------------------- nm_project

@settings(**SETTINGS)
@given(g=st.integers(1, 64), pattern=st.sampled_from([(2, 4), (4, 8), (1, 4), (3, 8)]),
       seed=st.integers(0, 2**31 - 1))
def test_nm_project_matches_ref(g, pattern, seed):
    n_keep, m = pattern
    z = rand((g, m), seed)
    np.testing.assert_allclose(
        np.asarray(knm.nm_project(z, n_keep)),
        np.asarray(ref.nm_project(z, n_keep)), rtol=1e-6)


@settings(**SETTINGS)
@given(g=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_nm_project_row_budget(g, seed):
    z = rand((g, 4), seed)
    out = np.asarray(knm.nm_project(z, 2))
    assert (np.count_nonzero(out, axis=1) <= 2).all()


def test_nm_project_ties_stable():
    z = np.array([[1.0, -1.0, 1.0, 1.0]], dtype=np.float32)
    out = np.asarray(knm.nm_project(z, 2))
    # stable: keeps the two lowest-index entries among equal magnitudes
    np.testing.assert_array_equal(out, [[1.0, -1.0, 0.0, 0.0]])


def test_nm_project_matrix_columns_grouped_along_input_dim():
    w = rand((8, 3), 0)
    out = np.asarray(knm.nm_project_matrix(w, 2, 4))
    # each column has 8/4 = 2 groups of 4, each keeping <= 2
    for j in range(3):
        col = out[:, j]
        assert np.count_nonzero(col[:4]) <= 2
        assert np.count_nonzero(col[4:]) <= 2


def test_nm_project_preserves_values():
    z = rand((32, 4), 1)
    out = np.asarray(knm.nm_project(z, 2))
    nz = out != 0
    np.testing.assert_array_equal(out[nz], z[nz])


# ---------------------------------------------------------------- topk_mask

@settings(**SETTINGS)
@given(m=st.integers(1, 80), n=st.integers(1, 80),
       t=st.floats(0.0, 3.0), seed=st.integers(0, 2**31 - 1))
def test_topk_mask_matches_ref(m, n, t, seed):
    x = rand((m, n), seed)
    np.testing.assert_allclose(
        np.asarray(ktm.topk_mask(x, t)), np.asarray(ref.topk_mask(x, t)))


def test_topk_mask_zero_threshold_keeps_all():
    x = rand((16, 16), 0)
    np.testing.assert_array_equal(np.asarray(ktm.topk_mask(x, 0.0)), x)


def test_topk_mask_huge_threshold_zeroes_all():
    x = rand((16, 16), 0)
    assert np.count_nonzero(np.asarray(ktm.topk_mask(x, 1e9))) == 0


# ---------------------------------------------------------------- pcg_step

@settings(**SETTINGS)
@given(m=st.integers(1, 64), n=st.integers(1, 64),
       alpha=st.floats(-2.0, 2.0), seed=st.integers(0, 2**31 - 1))
def test_pcg_elementwise_matches_ref(m, n, alpha, seed):
    w, p, r, hp = (rand((m, n), seed + i) for i in range(4))
    mask = (rand((m, n), seed + 4) > 0).astype(np.float32)
    invd = np.abs(rand((m, 1), seed + 5)) + 0.1
    out = kpcg.pcg_elementwise(w, p, r, hp, mask, invd, alpha)
    expect = ref.pcg_elementwise(w, p, r, hp, mask, invd, alpha)
    for o, e in zip(out, expect):
        np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                   rtol=1e-5, atol=1e-6)


def test_pcg_elementwise_respects_mask():
    m, n = 16, 8
    w, p, r, hp = (rand((m, n), i) for i in range(4))
    mask = np.zeros((m, n), np.float32)
    mask[:4] = 1.0
    invd = np.ones((m, 1), np.float32)
    _, r_new, z_new = kpcg.pcg_elementwise(w, p, r, hp, mask, invd, 0.5)
    assert np.count_nonzero(np.asarray(r_new)[4:]) == 0
    assert np.count_nonzero(np.asarray(z_new)[4:]) == 0


# -------------------------------------------------------- topk (oracle only)

@settings(**SETTINGS)
@given(m=st.integers(1, 32), n=st.integers(1, 32), seed=st.integers(0, 2**31 - 1),
       frac=st.floats(0.05, 0.95))
def test_topk_project_exact_count(m, n, seed, frac):
    x = rand((m, n), seed)
    k = max(1, int(frac * m * n))
    out = np.asarray(ref.topk_project(x, k))
    assert np.count_nonzero(out) == k


def test_topk_project_is_euclidean_projection():
    # brute force on a small matrix: top-k keeps the k largest magnitudes
    x = np.array([[3.0, -1.0], [0.5, -2.0]], dtype=np.float32)
    out = np.asarray(ref.topk_project(x, 2))
    np.testing.assert_array_equal(out, [[3.0, 0.0], [0.0, -2.0]])
