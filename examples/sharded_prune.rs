//! Distributed pruning tour: shard a `PruneSession` across a pool of
//! workers and watch per-worker progress — all in one process over
//! loopback, so no setup is needed. Exercises the v2 protocol: the
//! engine keeps its worker connections alive across blocks, ships raw
//! activations instead of grams (the workers build H themselves), and
//! the workers heartbeat while solving so a dead pool member is detected
//! in seconds.
//!
//!     cargo run --release --example sharded_prune
//!
//! Across machines the same topology is two shell commands:
//!
//! ```text
//! hostA$ alps worker --addr 0.0.0.0:7979
//! hostB$ alps worker --addr 0.0.0.0:7979
//! coord$ alps prune --random --model alps-tiny --method alps --sparsity 0.7 \
//!            --workers hostA:7979,hostB:7979 --ship-activations \
//!            --status-addr 127.0.0.1:7878
//! coord$ curl http://127.0.0.1:7878/status   # live JSON progress
//! ```

use alps::config::{AlpsConfig, ModelConfig, SparsityTarget};
use alps::coordinator::{ShardedConfig, ShardedEngine};
use alps::data::synthetic_windows;
use alps::model::Model;
use alps::pruning::worker::{Worker, WorkerConfig};
use alps::pruning::{MethodSpec, ProgressEvent, PruneSession};
use std::net::TcpListener;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // --- 1. a pool of two workers (each would be `alps worker` on its own
    // host; here they share the process to stay runnable anywhere)
    let mut workers = Vec::new();
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(listener.local_addr()?.to_string());
        let worker = Arc::new(Worker::new(WorkerConfig::default()));
        let w = worker.clone();
        std::thread::spawn(move || {
            let _ = w.serve(listener);
        });
        workers.push(worker);
    }
    println!("worker pool: {}", addrs.join(", "));

    // --- 2. a sharded engine is just another `Engine` for the session;
    // `ship_activations` sends a layer's calibration rows X instead of
    // the O(n_in^2) gram whenever X is strictly smaller — with 2
    // calibration windows (256 rows) that's the wide mlp.w2 layers
    // (n_in = d_ff = 512), while the square 128-input layers keep the
    // smaller gram: the engine picks the cheaper encoding per layer.
    // The pool's connections persist across the model's blocks (one
    // dial per worker for the whole run).
    let cfg = ModelConfig::preset("alps-tiny")?;
    let mut model = Model::random(cfg.clone(), 7)?;
    let calib = synthetic_windows(2, cfg.seq_len, cfg.vocab, 0xCA11B);
    let spec = MethodSpec::Alps(AlpsConfig { max_iters: 120, ..Default::default() });
    let engine = ShardedEngine::with_config(
        spec,
        addrs,
        ShardedConfig { ship_activations: true, ..Default::default() },
    )?;

    // --- 3. the observer sees which pool member solved each layer (the
    // same attribution `--status-addr` serves as JSON over TCP)
    let report = PruneSession::builder()
        .calib(calib)
        .target(SparsityTarget::parse("0.7")?)
        .engine(Box::new(engine))
        .observer(|ev| {
            if let ProgressEvent::LayerSolved { block, layer, worker, secs, .. } = ev {
                println!(
                    "  [{block}] {layer} solved by {} in {secs:.2}s",
                    worker.as_deref().unwrap_or("local"),
                );
            }
        })
        .run(&mut model)?;
    println!("-> {}", report.summary());

    for (i, w) in workers.iter().enumerate() {
        println!(
            "worker {i}: {} layers solved over {} connection(s)",
            w.layers_solved(),
            w.connections_accepted(),
        );
        w.request_shutdown();
    }
    Ok(())
}
