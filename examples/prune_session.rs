//! `PruneSession` tour: the typed builder API for end-to-end pruning —
//! method specs with hyperparameters, streaming progress events, and
//! per-block checkpoint/resume.
//!
//!     cargo run --release --example prune_session
//!
//! No artifacts needed: the example prunes a synthetic random model with
//! synthetic calibration data.

use alps::config::{AlpsConfig, ModelConfig, SparsityTarget};
use alps::data::synthetic_windows;
use alps::model::Model;
use alps::pruning::{MethodSpec, ProgressEvent, PruneSession};

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::preset("alps-tiny")?;

    // --- 1. a plain run: typed method spec, custom hyperparameters
    let mut model = Model::random(cfg.clone(), 7)?;
    let calib = synthetic_windows(8, cfg.seq_len, cfg.vocab, 0xCA11B);
    let spec = MethodSpec::Alps(AlpsConfig { max_iters: 120, ..Default::default() });
    println!("== 1. prune alps-tiny (random weights) to 60% with {} ==", spec.label());
    let report = PruneSession::builder()
        .calib(calib.clone())
        .target(SparsityTarget::parse("0.6")?)
        .method(spec)
        .verbose(true)
        .run(&mut model)?;
    println!("-> {}\n", report.summary());

    // --- 2. streaming progress through an observer callback
    println!("== 2. observer: one line per block, a summary per layer kind ==");
    let mut model = Model::random(cfg.clone(), 7)?;
    let report = PruneSession::builder()
        .calib(calib.clone())
        .target(SparsityTarget::parse("0.6")?)
        .method(MethodSpec::Wanda)
        .observer(|ev| match ev {
            ProgressEvent::BlockStarted { block, n_blocks, .. } => {
                println!("   block {}/{} ...", block + 1, n_blocks);
            }
            ProgressEvent::LayerSolved { layer, rel_error, .. } => {
                println!("     {layer}: rel-err {rel_error:.4}");
            }
            _ => {}
        })
        .run(&mut model)?;
    println!("-> {}\n", report.summary());

    // --- 3. checkpoint/resume: stop after one block, resume, verify
    println!("== 3. checkpoint after every block; resume an interrupted run ==");
    let ck = std::env::temp_dir().join("alps_example_ck");
    let _ = std::fs::remove_dir_all(&ck);
    let mut interrupted = Model::random(cfg.clone(), 7)?;
    PruneSession::builder()
        .calib(calib.clone())
        .target(SparsityTarget::parse("0.6")?)
        .method(MethodSpec::Wanda)
        .checkpoint_dir(&ck)
        .stop_after(1) // simulate the interruption
        .run(&mut interrupted)?;
    println!("   interrupted after block 0 (checkpoint in {})", ck.display());

    let mut resumed = Model::random(cfg.clone(), 7)?;
    PruneSession::builder()
        .calib(calib.clone())
        .target(SparsityTarget::parse("0.6")?)
        .method(MethodSpec::Wanda)
        .checkpoint_dir(&ck)
        .resume(true)
        .run(&mut resumed)?;

    let mut uninterrupted = Model::random(cfg, 7)?;
    PruneSession::builder()
        .calib(calib)
        .target(SparsityTarget::parse("0.6")?)
        .method(MethodSpec::Wanda)
        .run(&mut uninterrupted)?;

    let identical = uninterrupted
        .weights
        .tensors
        .iter()
        .all(|(name, t)| resumed.weights.tensors[name].data == t.data);
    println!("   resumed == uninterrupted, bit-for-bit: {identical}");
    assert!(identical);
    Ok(())
}
