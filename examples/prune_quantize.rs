//! Prune-then-quantize (the paper's future-work combination): ALPS to 50%
//! sparsity, then symmetric int8 per-channel quantization of the
//! survivors, with ALPS-style calibration-aware scale re-fitting.
//!
//!     make artifacts && cargo run --release --example prune_quantize

use alps::config::SparsityTarget;
use alps::data::{sample_windows, Corpus};
use alps::eval::perplexity;
use alps::model::Model;
use alps::pruning::quantize::{prune_quantize_error, QuantizedWeights};
use alps::pruning::session::single_layer_problem;
use alps::pruning::{LayerProblem, MethodSpec, PruneMethod, PruneSession};
use alps::util::table::{fmt_sig, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let corpus = Corpus::load(&dir.join("corpus.bin"))?;
    let mut model = Model::load(dir, "alps-tiny")?;
    let calib = sample_windows(corpus.split("train")?, 16, model.cfg.seq_len, 17);
    let eval_ids = corpus.split("wikitext2-like")?;
    let ppl_dense = perplexity(&model, eval_ids)?;

    // --- single-layer view: error decomposition
    println!("single-layer prune(0.5)+int8 on blocks.0.mlp.w2:\n");
    let p = single_layer_problem(&model, &calib, 0, "mlp.w2")?;
    let pruned = alps::pruning::alps::Alps::default()
        .prune(&p, SparsityTarget::Unstructured(0.5))?;
    let (err_rtn, err_refit, q) = prune_quantize_error(&p, &pruned);
    let mut t = Table::new(&["stage", "rel-error", "bits/weight"]);
    t.row(&["pruned fp32".into(), fmt_sig(p.rel_error(&pruned)), "32 (dense acct.)".into()]);
    t.row(&["+ int8 RTN".into(), fmt_sig(err_rtn), format!("{:.2}", q.bits_per_weight())]);
    t.row(&["+ scale re-fit".into(), fmt_sig(err_refit), format!("{:.2}", q.bits_per_weight())]);
    t.print();

    // --- whole model: prune everything, quantize every prunable matrix
    println!("\nwhole-model prune(0.5)+int8, perplexity:\n");
    PruneSession::builder()
        .calib(calib.clone())
        .target(SparsityTarget::Unstructured(0.5))
        .method(MethodSpec::parse("alps")?)
        .run(&mut model)?;
    let ppl_pruned = perplexity(&model, eval_ids)?;

    // quantize in place (with calibration-aware refit per layer)
    for block in 0..model.cfg.n_layers {
        let inputs = model.forward_collect(&calib, block)?;
        for (name, tap) in alps::model::prunable_layers(block) {
            let x = &inputs.taps[&tap];
            let w = model.weights.matrix(&name)?;
            let problem = LayerProblem::from_activations(x, &w)?;
            let mut q = QuantizedWeights::quantize(&w);
            q.refit_scales(&problem);
            model.weights.set_matrix(&name, &q.dequantize())?;
        }
    }
    let ppl_quant = perplexity(&model, eval_ids)?;

    let mut t = Table::new(&["model", "wikitext2-like ppl"]);
    t.row(&["dense fp32".into(), fmt_sig(ppl_dense)]);
    t.row(&["ALPS 50% fp32".into(), fmt_sig(ppl_pruned)]);
    t.row(&["ALPS 50% + int8".into(), fmt_sig(ppl_quant)]);
    t.print();
    println!(
        "\nint8 on top of 50% sparsity should cost almost no perplexity —\n\
         the compression axes compose (paper conclusion's future-work claim)."
    );
    Ok(())
}
