//! End-to-end driver (the EXPERIMENTS.md §E2E run): load the trained
//! alps-base transformer, prune it to 70% with ALPS through the **HLO
//! artifact engine** (rust coordinator -> PJRT -> AOT-compiled JAX/Pallas
//! graphs), evaluate perplexity + zero-shot before/after, and compare to
//! magnitude pruning — proving all three layers compose on a real workload.
//!
//!     make artifacts && cargo run --release --example prune_transformer
//!     # flags: --model alps-tiny|alps-small|alps-base  --sparsity 0.7
//!     #        --engine hlo|native

use alps::config::{AlpsConfig, SparsityTarget};
use alps::data::{sample_windows, tasks, Corpus};
use alps::eval::{perplexity, zero_shot_accuracy};
use alps::model::Model;
use alps::pruning::{HloEngine, MethodSpec, PruneSession};
use alps::runtime::Runtime;
use alps::util::table::{fmt_sig, Table};
use alps::util::Timer;
use std::path::Path;

fn flag(args: &[String], key: &str, default: &str) -> String {
    args.windows(2)
        .find(|w| w[0] == format!("--{key}"))
        .map(|w| w[1].clone())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = flag(&args, "model", "alps-base");
    let sparsity = flag(&args, "sparsity", "0.7");
    let engine_kind = flag(&args, "engine", "hlo");
    let dir = Path::new("artifacts");

    let corpus = Corpus::load(&dir.join("corpus.bin"))?;
    let dense = Model::load(dir, &model_name)?;
    let target = SparsityTarget::parse(&sparsity)?;
    println!(
        "== ALPS end-to-end: {} ({} params, {} blocks) -> {} sparsity via {} engine ==\n",
        model_name,
        dense.weights.total_params(),
        dense.cfg.n_layers,
        target.label(),
        engine_kind
    );

    // calibration: 32 windows of seq_len tokens from the train split
    let calib = sample_windows(corpus.split("train")?, 32, dense.cfg.seq_len, 0xCA11B);

    // --- dense baseline metrics
    let eval_ids = corpus.split("wikitext2-like")?;
    let ppl_dense = perplexity(&dense, eval_ids)?;

    // --- prune with ALPS (HLO engine) and with MP (native)
    let rt = Runtime::new(dir)?;
    let mut m_alps = Model::load(dir, &model_name)?;
    let mut m_mp = Model::load(dir, &model_name)?;

    println!("pruning with ALPS ({engine_kind} engine):");
    let t = Timer::start();
    let alps_builder = PruneSession::builder()
        .calib(calib.clone())
        .target(target)
        .verbose(true);
    let rep_alps = if engine_kind == "hlo" {
        alps_builder
            .engine(Box::new(HloEngine::new(&rt, AlpsConfig::default())))
            .run(&mut m_alps)?
    } else {
        alps_builder
            .method(MethodSpec::Alps(AlpsConfig::default()))
            .run(&mut m_alps)?
    };
    let alps_secs = t.elapsed_secs();
    println!(
        "  -> {} ({} artifact executions)\n",
        rep_alps.summary(),
        rt.total_execs()
    );

    println!("pruning with MP (baseline):");
    let rep_mp = PruneSession::builder()
        .calib(calib)
        .target(target)
        .method(MethodSpec::Magnitude)
        .run(&mut m_mp)?;
    println!("  -> {}\n", rep_mp.summary());

    // --- evaluate everything
    println!("evaluating perplexity on 3 held-out sets + 4 zero-shot tasks ...");
    let mut table = Table::new(&["metric", "dense", "ALPS", "MP"]);
    for split in Corpus::eval_split_names() {
        let ids = corpus.split(split)?;
        table.row(&[
            format!("{split} ppl"),
            fmt_sig(perplexity(&dense, ids)?),
            fmt_sig(perplexity(&m_alps, ids)?),
            fmt_sig(perplexity(&m_mp, ids)?),
        ]);
    }
    for task in tasks::standard_tasks(eval_ids, 40, dense.cfg.seq_len, dense.cfg.vocab, 7) {
        table.row(&[
            format!("{} acc%", task.name),
            format!("{:.1}", zero_shot_accuracy(&dense, &task)? * 100.0),
            format!("{:.1}", zero_shot_accuracy(&m_alps, &task)? * 100.0),
            format!("{:.1}", zero_shot_accuracy(&m_mp, &task)? * 100.0),
        ]);
    }
    table.print();
    println!(
        "\nALPS prune time {:.1}s; dense ppl {:.3}; ALPS keeps perplexity far closer to dense than MP (paper Table 2 shape).",
        alps_secs, ppl_dense
    );
    Ok(())
}
