//! Support-quality ablation (paper Table 1, left): fix the support chosen
//! by each method, solve the restricted problem (6) to optimality with the
//! exact backsolve, and compare — isolating *where* each method's mask is
//! good from *what values* it assigns.
//!
//!     cargo run --release --example support_quality

use alps::config::SparsityTarget;
use alps::linalg::Matrix;
use alps::pruning::{backsolve, LayerProblem, MethodSpec};
use alps::util::table::{fmt_sig, Table};
use alps::util::Rng;

fn main() -> anyhow::Result<()> {
    let (n_in, n_out, rows) = (192, 96, 768);
    let mut rng = Rng::new(7);
    let mut x = Matrix::randn(rows, n_in, &mut rng);
    for c in 0..n_in {
        let s = 0.2 + 2.2 * ((c * 53 % n_in) as f32 / n_in as f32);
        for r in 0..rows {
            *x.at_mut(r, c) *= s;
        }
    }
    let what = Matrix::randn(n_in, n_out, &mut rng);
    let problem = LayerProblem::from_activations(&x, &what)?;

    println!(
        "support quality on a {n_in}x{n_out} layer: optimal weights on each\n\
         method's support (paper Table 1 left)\n"
    );
    let mut table = Table::new(&["sparsity", "MP", "Wanda", "SparseGPT", "DSnoT", "ALPS"]);
    for s in [0.5, 0.6, 0.7, 0.8, 0.9] {
        let target = SparsityTarget::Unstructured(s);
        let mut row = vec![format!("{s:.1}")];
        for spec in MethodSpec::all() {
            let w = spec.prune(&problem, target)?;
            let optimal = backsolve::solve_on_support(&problem, &w.support_mask())?;
            row.push(fmt_sig(problem.rel_error(&optimal)));
        }
        // reorder: methods come out mp, wanda, sparsegpt, dsnot, alps
        table.row(&row);
    }
    table.print();
    println!(
        "\nexpect the ALPS column lowest at every sparsity (the paper reports\n\
         20-40% lower error than the best heuristic support)."
    );
    Ok(())
}
