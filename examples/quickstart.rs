//! Quickstart: prune one linear layer with ALPS and compare against the
//! baselines — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! No artifacts needed: this example builds a synthetic layer problem and
//! uses the pure-rust native path.

use alps::config::SparsityTarget;
use alps::linalg::Matrix;
use alps::pruning::{LayerProblem, MethodSpec};
use alps::util::table::{fmt_sig, Table};
use alps::util::{Rng, Timer};

fn main() -> anyhow::Result<()> {
    // --- build a layer problem: dense weights + calibration activations
    let (n_in, n_out, n_samples) = (256, 128, 1024);
    let mut rng = Rng::new(42);
    let mut x = Matrix::randn(n_samples, n_in, &mut rng);
    // realistic activations are anisotropic — scale feature columns
    for c in 0..n_in {
        let s = 0.2 + 2.0 * (c as f32 / n_in as f32);
        for r in 0..n_samples {
            *x.at_mut(r, c) *= s;
        }
    }
    let what = Matrix::randn(n_in, n_out, &mut rng);
    let problem = LayerProblem::from_activations(&x, &what)?;

    // --- prune to 70% sparsity with every method
    let target = SparsityTarget::Unstructured(0.7);
    println!(
        "pruning a {n_in}x{n_out} layer to {} sparsity ({} of {} weights kept)\n",
        target.label(),
        target.keep_count(n_in, n_out),
        n_in * n_out
    );
    let mut table = Table::new(&["method", "rel-error", "time (s)"]);
    for spec in MethodSpec::all() {
        let timer = Timer::start();
        let w = spec.prune(&problem, target)?;
        let secs = timer.elapsed_secs();
        table.row(&[
            spec.label().to_string(),
            fmt_sig(problem.rel_error(&w)),
            format!("{secs:.3}"),
        ]);
    }
    table.print();
    println!("\nALPS should show the lowest reconstruction error (paper Fig. 2).");
    Ok(())
}
