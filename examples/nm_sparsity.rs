//! N:M structured sparsity demo (paper Sec. 4.3 / Table 3): prune the
//! trained alps-tiny model to 2:4 and 4:8 patterns with ALPS and the
//! baselines, verify the hardware pattern holds, and report perplexity.
//!
//!     make artifacts && cargo run --release --example nm_sparsity

use alps::config::SparsityTarget;
use alps::data::{sample_windows, Corpus};
use alps::eval::perplexity;
use alps::linalg::Csr;
use alps::model::Model;
use alps::pruning::{MethodSpec, PruneSession};
use alps::sparse::NmPacked;
use alps::util::table::{fmt_sig, Table};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let corpus = Corpus::load(&dir.join("corpus.bin"))?;
    let dense = Model::load(dir, "alps-tiny")?;
    let calib = sample_windows(corpus.split("train")?, 16, dense.cfg.seq_len, 3);
    let eval_ids = corpus.split("wikitext2-like")?;
    let ppl_dense = perplexity(&dense, eval_ids)?;
    println!("dense alps-tiny ppl: {ppl_dense:.3}\n");

    let mut table = Table::new(&["pattern", "method", "wikitext2-like ppl", "mean layer err"]);
    for pattern in ["2:4", "4:8"] {
        let target = SparsityTarget::parse(pattern)?;
        for method in ["mp", "wanda", "sparsegpt", "alps"] {
            let mut model = Model::load(dir, "alps-tiny")?;
            let report = PruneSession::builder()
                .calib(calib.clone())
                .target(target)
                .method(MethodSpec::parse(method)?)
                .run(&mut model)?;
            // verify the hardware pattern on every pruned matrix
            for name in model.prunable_names() {
                let w = model.weights.matrix(&name)?;
                assert!(
                    alps::pruning::check_target(&w, target),
                    "{method} violated {pattern} on {name}"
                );
            }
            table.row(&[
                pattern.to_string(),
                method.to_string(),
                fmt_sig(perplexity(&model, eval_ids)?),
                fmt_sig(report.mean_rel_error()),
            ]);
        }
    }
    table.print();

    // show the sparse-inference payoff: CSR matmul skips the zeros, and
    // the packed N:M format drops the indptr + u32 indices entirely
    // (2 bits per kept weight for 2:4) — the format `alps serve
    // --format nm` decodes from, bit-identically to CSR
    let mut model = Model::load(dir, "alps-tiny")?;
    PruneSession::builder()
        .calib(calib)
        .target(SparsityTarget::parse("2:4")?)
        .method(MethodSpec::parse("alps")?)
        .run(&mut model)?;
    let w = model.weights.matrix("blocks.0.mlp.w1")?;
    let csr = Csr::from_dense(&w);
    let packed = NmPacked::from_dense(&w, 2, 4)?;
    let dense_bytes = w.rows * w.cols * 4;
    println!(
        "\nblocks.0.mlp.w1 as CSR: {} non-zeros of {} ({:.0}% dense) — the
2:4 pattern maps directly onto sparse-tensor-core hardware (paper Sec. 3.2).",
        csr.nnz(),
        w.rows * w.cols,
        csr.density() * 100.0
    );
    println!(
        "packed 2:4: {} bytes vs {} CSR vs {} dense ({:.0}% / {:.0}% of dense)",
        packed.bytes(),
        csr.bytes(),
        dense_bytes,
        packed.bytes() as f64 / dense_bytes as f64 * 100.0,
        csr.bytes() as f64 / dense_bytes as f64 * 100.0,
    );
    Ok(())
}
